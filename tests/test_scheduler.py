"""Unit + property tests for FedHC's Algorithm 1 and the greedy baseline."""
from collections import deque

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # dev extra absent: deterministic mini-sampler
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.budget import ClientBudget
from repro.core.scheduler import FedHCScheduler, GreedyScheduler


def _clients(budgets):
    return [ClientBudget(i, b) for i, b in enumerate(budgets)]


def test_double_pointer_small_and_large_first():
    # sorted: [10, 10, 15, 30, 40, 50, 65, 80] — left takes 10, right takes 80
    sched = FedHCScheduler(_clients([10, 15, 30, 80, 65, 40, 50, 10]), theta=100)
    sel = sched.select([], deque(range(8)))
    budgets = [e.budget for e in sel]
    assert budgets[0] == 10 and budgets[1] == 80
    assert sum(budgets) <= 100


def test_left_pointer_fills_after_right_stops():
    sched = FedHCScheduler(_clients([10, 10, 10, 90]), theta=100)
    sel = sched.select([], deque(range(4)))
    budgets = sorted(e.budget for e in sel)
    # 10 + 90 admitted; right stops; left keeps filling nothing (sum=100)
    assert sum(e.budget for e in sel) <= 100
    assert 90 in [e.budget for e in sel]


def test_greedy_head_of_line_blocking():
    sched = GreedyScheduler(_clients([10, 15, 30, 80, 5]), theta=100)
    sel = sched.select([], deque(range(5)))
    # FIFO admits 10,15,30 (=55); 80 blocks; the 5 behind it never runs
    assert [e.budget for e in sel] == [10, 15, 30]


def test_executor_starvation_blocks_admission():
    sched = FedHCScheduler(_clients([10, 20, 30]), theta=100)
    sel = sched.select([], deque([0]))  # single executor slot
    assert len(sel) == 1


def test_respects_running_budgets():
    sched = FedHCScheduler(_clients([50, 60]), theta=100)
    sel = sched.select([70.0], deque(range(4)))
    assert sum(e.budget for e in sel) + 70.0 <= 100


@settings(max_examples=200, deadline=None)
@given(
    budgets=st.lists(st.integers(1, 100).map(float), min_size=1, max_size=40),
    theta=st.floats(10, 150),
    n_exec=st.integers(1, 32),
)
def test_property_never_exceeds_theta(budgets, theta, n_exec):
    sched = FedHCScheduler(_clients(budgets), theta=theta)
    sel = sched.select([], deque(range(n_exec)))
    total = sum(e.budget for e in sel)
    # Alg 1 admits only while each client fits under theta
    assert total <= theta + 1e-9
    assert len(sel) <= n_exec
    # no duplicate executors, no duplicate clients
    assert len({e.executor_id for e in sel}) == len(sel)
    assert len({e.client_id for e in sel}) == len(sel)


@settings(max_examples=100, deadline=None)
@given(budgets=st.lists(st.integers(1, 60).map(float), min_size=1, max_size=30))
def test_property_all_clients_eventually_scheduled(budgets):
    """Repeatedly draining the running set must schedule everyone exactly once."""
    sched = FedHCScheduler(_clients(budgets), theta=100)
    seen = []
    guard = 0
    while not sched.done:
        guard += 1
        assert guard < 1000
        sel = sched.select([], deque(range(64)))
        assert sel, "scheduler made no progress"
        seen.extend(e.client_id for e in sel)
    assert sorted(seen) == list(range(len(budgets)))


@settings(max_examples=60, deadline=None)
@given(
    budgets=st.lists(st.integers(5, 100).map(float), min_size=3, max_size=25),
    seed=st.integers(0, 100),
)
def test_property_fedhc_round_no_slower_than_greedy_on_average(budgets, seed):
    """Across equal-work rounds FedHC's duration ≤ greedy's (+small slack:
    the double-pointer heuristic can lose on adversarial 2-client cases but
    must not lose on aggregate rounds)."""
    from repro.core.simulator import RoundSimulator, SimClient

    clients = [SimClient(i, b, 5.0) for i, b in enumerate(budgets)]
    f, _ = RoundSimulator(FedHCScheduler, max_parallel=64).run(clients)
    g, _ = RoundSimulator(GreedyScheduler, max_parallel=64).run(clients)
    assert f.duration <= g.duration * 1.35 + 1e-6
