"""Kernel sweeps: every Pallas kernel against its pure-jnp oracle across
shapes and dtypes (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.grouped_matmul import ops as gmm_ops
from repro.kernels.grouped_matmul import ref as gmm_ref
from repro.kernels.rglru_scan import ops as lru_ops
from repro.kernels.rglru_scan import ref as lru_ref
from repro.kernels.ssd_scan import ops as ssd_ops
from repro.kernels.ssd_scan import ref as ssd_ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


# ----------------------------- flash attention -----------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,hq,hk,d,causal,window",
    [
        (1, 128, 4, 4, 32, True, None),
        (2, 256, 8, 2, 64, True, None),     # GQA
        (2, 256, 8, 2, 64, True, 64),       # sliding window
        (1, 384, 4, 1, 32, True, 128),      # MQA + window, non-pow2 seq
        (2, 128, 4, 4, 64, False, None),    # bidirectional (encoder)
    ],
)
def test_flash_attention_sweep(b, s, hq, hk, d, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, hq, d), dtype)
    k = jax.random.normal(ks[1], (b, s, hk, d), dtype)
    v = jax.random.normal(ks[2], (b, s, hk, d), dtype)
    ref = fa_ref.attention_ref(q, k, v, causal=causal, window=window)
    out = fa_ops.flash_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


def test_flash_attention_grads_match_reference():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 32))
    k = jax.random.normal(ks[1], (1, 128, 2, 32))
    v = jax.random.normal(ks[2], (1, 128, 2, 32))
    g1 = jax.grad(lambda q, k, v: fa_ops.flash_attention(q, k, v).sum(), (0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda q, k, v: fa_ref.attention_ref(q, k, v).sum(), (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


# ----------------------------- SSD scan ------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,l,h,p,g,n,chunk",
    [
        (1, 64, 2, 8, 1, 8, 16),
        (2, 128, 4, 16, 2, 16, 32),
        (1, 96, 4, 8, 1, 16, 32),  # L not divisible by chunk (padding path)
    ],
)
def test_ssd_sweep(b, l, h, p, g, n, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (b, l, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h))).astype(jnp.float32)
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    bm = jax.random.normal(ks[3], (b, l, g, n), dtype)
    cm = jax.random.normal(ks[4], (b, l, g, n), dtype)
    y_ref, s_ref = ssd_ref.ssd_sequential(x, dt, a, bm, cm)
    for impl in ("chunked", "pallas"):
        y, s = ssd_ops.ssd(x, dt, a, bm, cm, chunk=chunk, impl=impl)
        np.testing.assert_allclose(
            np.asarray(y, np.float32), np.asarray(y_ref, np.float32),
            **_tol(dtype),
        )
        np.testing.assert_allclose(
            np.asarray(s, np.float32), np.asarray(s_ref, np.float32),
            **_tol(dtype),
        )


def test_ssd_decode_chain_matches_scan():
    b, l, h, p, g, n = 1, 8, 2, 4, 1, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    bm = jax.random.normal(ks[3], (b, l, g, n))
    cm = jax.random.normal(ks[4], (b, l, g, n))
    y_ref, s_ref = ssd_ref.ssd_sequential(x, dt, a, bm, cm)
    st = jnp.zeros((b, h, p, n))
    for t in range(l):
        yt, st = ssd_ops.ssd_decode_step(st, x[:, t], dt[:, t], a, bm[:, t], cm[:, t])
    np.testing.assert_allclose(np.asarray(yt), np.asarray(y_ref[:, -1]), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(st), np.asarray(s_ref), rtol=1e-5, atol=1e-5)


# ----------------------------- RG-LRU --------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,l,w", [(1, 64, 32), (2, 256, 128), (2, 96, 64)])
def test_rglru_sweep(b, l, w, dtype):
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    log_a = -jax.nn.softplus(jax.random.normal(ks[0], (b, l, w))).astype(jnp.float32)
    bb = jax.random.normal(ks[1], (b, l, w), dtype)
    y_ref, h_ref = lru_ref.rglru_sequential(log_a, bb)
    for impl in ("associative", "pallas"):
        if impl == "pallas" and l % 32 != 0:
            continue
        y, h = lru_ops.rglru_scan(log_a, bb, impl=impl)
        np.testing.assert_allclose(
            np.asarray(y, np.float32), np.asarray(y_ref, np.float32), **_tol(dtype)
        )
        np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), rtol=1e-4, atol=1e-4)


# ----------------------------- grouped matmul ------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "m,kdim,n,groups",
    [(128, 32, 64, 4), (256, 64, 96, 8), (64, 16, 32, 3)],
)
def test_gmm_sweep(m, kdim, n, groups, dtype):
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (m, kdim), dtype)
    w = jax.random.normal(jax.random.PRNGKey(6), (groups, kdim, n), dtype)
    # random group sizes incl. empty groups
    rng = np.random.default_rng(0)
    cuts = np.sort(rng.integers(0, m + 1, size=groups - 1))
    gs = jnp.asarray(np.diff(np.concatenate([[0], cuts, [m]])), jnp.int32)
    ref = gmm_ref.grouped_matmul_ref(x, w, gs)
    for impl in ("ragged", "pallas"):
        y = gmm_ops.grouped_matmul(x, w, gs, impl=impl)
        np.testing.assert_allclose(
            np.asarray(y, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
        )


def test_gmm_grads_match_between_impls():
    m, kdim, n, groups = 128, 32, 64, 4
    x = jax.random.normal(jax.random.PRNGKey(7), (m, kdim))
    w = jax.random.normal(jax.random.PRNGKey(8), (groups, kdim, n))
    gs = jnp.array([32, 0, 64, 32], jnp.int32)
    f = lambda x, w, impl: (gmm_ops.grouped_matmul(x, w, gs, impl=impl) ** 2).sum()
    g1 = jax.grad(f, (0, 1))(x, w, "ragged")
    g2 = jax.grad(f, (0, 1))(x, w, "pallas")
    np.testing.assert_allclose(np.asarray(g1[0]), np.asarray(g2[0]), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(g1[1]), np.asarray(g2[1]), rtol=1e-5, atol=1e-5)


# ----------------------------- int8 flash-decode ---------------------------


@pytest.mark.parametrize(
    "b,hq,hk,s,d,kv_len,tk",
    [(1, 4, 4, 128, 32, 100, 32), (2, 8, 2, 256, 64, 200, 64), (1, 4, 1, 512, 64, 511, 128)],
)
def test_flash_decode_int8_sweep(b, hq, hk, s, d, kv_len, tk):
    """Split-KV decode kernel with in-kernel dequant vs the dequantized
    oracle (exact) and the fp cache (within quantization error)."""
    from repro.kernels.flash_attention.decode_kernel import flash_decode_int8
    from repro.models import layers as L

    ks = jax.random.split(jax.random.PRNGKey(10), 3)
    q = jax.random.normal(ks[0], (b, hq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hk, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hk, d), jnp.float32)
    kq, kscale = L.quantize_kv(k)
    vq, vscale = L.quantize_kv(v)
    o = flash_decode_int8(
        q, kq.transpose(0, 2, 1, 3), vq.transpose(0, 2, 1, 3),
        kscale.transpose(0, 2, 1), vscale.transpose(0, 2, 1),
        kv_len=kv_len, tk=tk, interpret=True,
    )
    kd, vd = L.dequantize_kv(kq, kscale), L.dequantize_kv(vq, vscale)
    qpos = jnp.full((b, 1), kv_len - 1)
    kvpos = jnp.broadcast_to(
        jnp.where(jnp.arange(s) < kv_len, jnp.arange(s), -1), (b, s)
    )
    ref = L.attention_reference(q[:, None], kd, vd, qpos, kvpos, causal=False)[:, 0]
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref), rtol=1e-5, atol=1e-5)
    ref_fp = L.attention_reference(q[:, None], k, v, qpos, kvpos, causal=False)[:, 0]
    assert float(jnp.abs(o - ref_fp).max()) < 0.05  # quantization-bounded
