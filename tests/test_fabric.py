"""Multi-tenant resource fabric tests: arbiter leasing + fairness,
no-starvation, work-conserving borrowing, capacity events as first-class
campaign heap events, and the aggregate-throughput win of sharing one pool
across concurrent campaigns."""
import random
import time

import pytest

from repro.core.campaign import CampaignEngine, CapacityEvent, SimClient
from repro.core.fabric import (
    PoolFabric,
    ResourceArbiter,
    weighted_maxmin,
)
from repro.core.scheduler import FedHCScheduler, GreedyScheduler


# ------------------- weighted max-min (capacity grants) ---------------------


def test_weighted_maxmin_satisfies_small_demands_first():
    g = weighted_maxmin({"a": 10.0, "b": 200.0}, {"a": 1.0, "b": 1.0}, 100.0)
    assert g["a"] == pytest.approx(10.0)      # fits under its share: full
    assert g["b"] == pytest.approx(90.0)      # takes all the leftover


def test_weighted_maxmin_respects_weights_under_saturation():
    g = weighted_maxmin({"a": 500.0, "b": 500.0}, {"a": 3.0, "b": 1.0}, 100.0)
    assert g["a"] == pytest.approx(75.0)
    assert g["b"] == pytest.approx(25.0)


def test_weighted_maxmin_work_conserving():
    # idle tenant's share flows to the busy ones; nothing is wasted
    g = weighted_maxmin({"a": 80.0, "b": 0.0, "c": 80.0},
                        {"a": 1.0, "b": 1.0, "c": 1.0}, 100.0)
    assert g["b"] == 0.0
    assert g["a"] + g["c"] == pytest.approx(100.0)
    assert g["a"] == pytest.approx(50.0)


# ------------------- slot leasing ------------------------------------------


def test_arbiter_firm_and_soft_leases():
    arb = ResourceArbiter(total_slots=4, lease_ttl=3.0)
    a = arb.register("a", weight=1.0)
    arb.register("b", weight=1.0)
    # within fair share (2): firm leases, no expiry
    s0, s1 = a.popleft(), a.popleft()
    assert not arb.tenants["a"].leases[s0].soft
    # above share: work-conserving soft lease with expiry (b isn't starved)
    s2 = a.popleft()
    lease = arb.tenants["a"].leases[s2]
    assert lease.soft and lease.expires == pytest.approx(3.0)
    a.append(s1)
    assert arb.free_count() == 2
    assert s1 not in arb.tenants["a"].leases


def test_arbiter_denies_borrow_while_other_starves():
    arb = ResourceArbiter(total_slots=4, lease_ttl=3.0)
    a = arb.register("a", weight=1.0)
    b = arb.register("b", weight=1.0)
    a.popleft(), a.popleft(), a.popleft()       # a holds 3 of 4 (1 soft)
    arb.note_starved("b")                       # b (held 0 < share 2) waits
    assert not arb.can_acquire("a")             # no more borrowing
    assert arb.can_acquire("b")                 # b's own share still open
    assert b.popleft() is not None


def test_arbiter_revokes_only_expired_soft_leases():
    arb = ResourceArbiter(total_slots=4, lease_ttl=3.0)
    a = arb.register("a", weight=1.0)
    arb.register("b", weight=1.0)
    slots = [a.popleft() for _ in range(4)]     # 2 firm + 2 soft
    arb.note_starved("b")
    assert arb.next_expiry() == pytest.approx(3.0)
    arb.now = 1.0
    assert arb.revocable() == []                # nothing expired yet
    arb.now = 3.0
    revoked = arb.revocable()
    assert {l.slot for l in revoked} <= set(slots)
    assert len(revoked) == 2 and all(l.soft for l in revoked)
    assert arb.revocable() == []                # marked once, not twice


# ------------------- fairness convergence ----------------------------------


def _flood(n, budget=5.0, work=100.0, base=0):
    return [SimClient(base + i, budget, work) for i in range(n)]


def _parallelism_at(result, t):
    for seg in result.rounds[0].timeline:
        if seg.t0 <= t < seg.t1:
            return seg.parallelism
    return 0


def test_weighted_fair_share_converges_to_3_to_1():
    """Two tenants with 3:1 weights under sustained load settle at a 3:1
    slot split (12/4 of 16), reached via preemption-on-lease-expiry."""
    fab = PoolFabric(total_slots=16, capacity=100.0, lease_ttl=2.0)
    ea = fab.add_tenant("A", weight=3.0)
    eb = fab.add_tenant("B", weight=1.0)
    res = fab.run({"A": [_flood(40)], "B": [_flood(40)]})
    assert res["A"].total_completed == 40
    assert res["B"].total_completed == 40
    # steady state, well past the lease TTL transient
    assert _parallelism_at(res["A"], 1000.0) == 12
    assert _parallelism_at(res["B"], 1000.0) == 4
    # the split was reached by revoking A's expired over-share leases
    assert ea.preemptions > 0
    assert fab.arbiter.revocations > 0
    assert eb.preemptions == 0
    # churn evictions stay zero: preemption is a separate counter
    assert res["A"].churn_evictions == 0


def test_no_starvation_bound_by_lease_ttl():
    """Whatever tenant A floods the pool with, tenant B schedules its first
    client within one lease TTL (the preemption bound)."""
    for seed in range(5):
        rng = random.Random(seed)
        ttl = rng.choice([1.0, 2.5, 5.0])
        fab = PoolFabric(total_slots=8, capacity=100.0, lease_ttl=ttl)
        fab.add_tenant("A", weight=1.0)
        fab.add_tenant("B", weight=1.0)
        wa = [_flood(rng.randint(16, 40), budget=rng.choice([5.0, 10.0]),
                     work=rng.uniform(50.0, 200.0))]
        wb = [_flood(6, budget=10.0, work=5.0, base=1000)]
        res = fab.run({"A": wa, "B": wb})
        assert res["B"].total_completed == 6
        first_start = min(s.start for s in res["B"].rounds[0].spans.values())
        assert first_start <= ttl + 1e-9, (seed, ttl, first_start)


def test_work_conserving_borrow_when_other_tenant_idle():
    """A lone busy tenant spreads over the whole pool, not just its share."""
    fab = PoolFabric(total_slots=16, capacity=100.0, lease_ttl=2.0)
    fab.add_tenant("A", weight=1.0)
    fab.add_tenant("B", weight=1.0)   # registered but no workload
    res = fab.run({"A": [_flood(20)]})
    assert res["A"].total_completed == 20
    assert _parallelism_at(res["A"], 50.0) == 16   # all slots, share is 8
    # and the full capacity: 16 × budget 5 = 80 admitted, all granted
    seg = [s for s in res["A"].rounds[0].timeline if s.t0 <= 50.0 < s.t1][0]
    assert seg.total_rate == pytest.approx(80.0)


def test_fabric_smoke_conservation():
    """2-tenant smoke: heterogeneous budgets, both schedulers, everything
    completes exactly once and granted rates never exceed the pool."""
    rng = random.Random(7)
    for sched in (FedHCScheduler, GreedyScheduler):
        fab = PoolFabric(total_slots=32, capacity=100.0, lease_ttl=3.0)
        fab.add_tenant("A", weight=2.0, scheduler_cls=sched)
        fab.add_tenant("B", weight=1.0, scheduler_cls=sched)
        mk = lambda n, base: [
            SimClient(base + i, rng.choice([5.0, 10.0, 25.0, 60.0]), 2.0)
            for i in range(n)
        ]
        res = fab.run({"A": [mk(30, 0), mk(30, 100)],
                       "B": [mk(30, 200), mk(30, 300)]})
        for tid in ("A", "B"):
            assert res[tid].total_completed == 60, sched
            assert res[tid].total_failed == 0
        # physical feasibility: per-instant granted rates sum ≤ capacity
        def rate_at(result, t):
            for rnd in result.rounds:
                for s in rnd.timeline:
                    if s.t0 <= t < s.t1:
                        return s.total_rate
            return 0.0

        edges = sorted({s.t0 for r in res.values()
                        for rnd in r.rounds for s in rnd.timeline})
        for t in edges:
            total = sum(rate_at(r, t) for r in res.values())
            assert total <= 100.0 + 1e-6, t


# ------------------- capacity events in the campaign heap -------------------


def test_capacity_event_is_first_class_heap_event():
    """A mid-round capacity drop posted at construction re-waterfills rates
    and sheds the largest executor through the scheduler requeue API."""
    clients = [SimClient(i, b, 5.0) for i, b in enumerate([40, 40, 20])]
    eng = CampaignEngine(
        FedHCScheduler, max_parallel=8,
        capacity_events=[CapacityEvent(2.0, 50.0, theta=50.0)],
    )
    res = eng.run_round(clients)
    assert res.completed == 3
    assert eng.capacity_evictions >= 1
    assert res.failed == []                  # shed ≠ failed: work re-runs
    for seg in res.timeline:
        if seg.t0 >= 2.0:
            assert seg.total_budget <= 50.0 + 1e-9
            assert seg.total_rate <= 50.0 + 1e-9


def test_capacity_event_posted_mid_campaign_spans_rounds():
    """post_capacity_event lands on the continuous campaign clock: a drop
    during round 0 still binds round 1, a later recovery lifts it."""
    clients = [SimClient(i, 50.0, 2.0) for i in range(4)]
    eng = CampaignEngine(FedHCScheduler, max_parallel=8)
    eng.post_capacity_event(CapacityEvent(1.0, 50.0))
    eng.post_capacity_event(CapacityEvent(6.0, 100.0))
    res = eng.run_campaign([clients, clients])
    assert res.total_completed == 8
    assert eng.capacity == 100.0
    # the shrunken middle stretch really ran at half pool
    mid = [s for r in res.rounds for s in r.timeline if 1.0 <= s.t0 < 6.0]
    assert mid and all(s.total_rate <= 50.0 + 1e-9 for s in mid)
    # and the campaign was slower than an un-shrunk one
    ref = CampaignEngine(FedHCScheduler, max_parallel=8).run_campaign(
        [clients, clients]
    )
    assert res.duration > ref.duration


def test_trailing_capacity_events_do_not_extend_campaign():
    clients = [SimClient(0, 50.0, 1.0)]
    eng = CampaignEngine(
        FedHCScheduler,
        capacity_events=[CapacityEvent(1000.0, 10.0)],
    )
    res = eng.run_round(clients)
    assert res.duration == pytest.approx(2.0)
    assert eng.now == pytest.approx(2.0)     # clock never ran to t=1000
    assert eng.capacity == 100.0             # the event never fired


# ------------------- aggregate throughput ----------------------------------


def _tail_rounds(seed, n_clients, per_round=10, work=2.0):
    """Federated rounds with straggler tails: a few fast big-budget
    devices, many slow small-budget ones (the regime where a lone campaign
    leaves most of the pool idle after the big clients drain)."""
    rng = random.Random(seed)
    rounds, cid = [], 0
    for _ in range(n_clients // per_round):
        cl = []
        for _ in range(per_round):
            cl.append(SimClient(cid, 80.0 if rng.random() < 0.12 else 5.0, work))
            cid += 1
        rounds.append(cl)
    return rounds


@pytest.mark.slow
def test_two_tenant_1000_clients_beats_serial_by_1_5x():
    """Acceptance: a 2-tenant 1000-client campaign on one shared pool
    completes with ≥1.5× aggregate throughput vs. running the two
    campaigns serially on the same capacity."""
    wa = _tail_rounds(1, 500)
    wb = _tail_rounds(2, 500)

    ra = CampaignEngine(FedHCScheduler, max_parallel=64).run_campaign(wa)
    rb = CampaignEngine(FedHCScheduler, max_parallel=64).run_campaign(wb)
    serial = ra.duration + rb.duration

    t0 = time.perf_counter()
    fab = PoolFabric(total_slots=64, capacity=100.0, lease_ttl=5.0)
    fab.add_tenant("A", weight=1.0)
    fab.add_tenant("B", weight=1.0)
    res = fab.run({"A": wa, "B": wb})
    wall = time.perf_counter() - t0

    assert res["A"].total_completed == 500
    assert res["B"].total_completed == 500
    shared = max(r.duration for r in res.values())
    speedup = serial / shared
    assert speedup >= 1.5, f"aggregate speedup {speedup:.2f} < 1.5"
    assert wall < 30.0, f"fabric run took {wall:.1f}s"


def test_fabric_tenants_with_availability_churn():
    """Tenancy composes with availability traces: churn on one tenant
    does not corrupt the other's accounting."""
    from repro.core.campaign import AvailabilityTrace

    clients = [SimClient(i, 20.0, 0.5) for i in range(12)]
    trace = AvailabilityTrace.periodic(
        [c.client_id for c in clients], period=8.0, duty=0.6,
        horizon=1000.0, seed=3,
    )
    fab = PoolFabric(total_slots=16, capacity=100.0, lease_ttl=2.0)
    fab.add_tenant("churny", weight=1.0, availability=trace)
    fab.add_tenant("steady", weight=1.0)
    res = fab.run({"churny": [clients] * 2,
                   "steady": [[SimClient(100 + i, 20.0, 0.5) for i in range(12)]] * 2})
    assert res["churny"].total_completed == 24
    assert res["steady"].total_completed == 24
    assert res["steady"].churn_evictions == 0
