"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional


@dataclass
class Row:
    name: str
    us_per_call: float
    derived: Dict[str, Any] = field(default_factory=dict)

    def csv(self) -> str:
        d = ";".join(f"{k}={_fmt(v)}" for k, v in self.derived.items())
        return f"{self.name},{_fmt(self.us_per_call)},{d}"


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def print_rows(rows: List[Row]) -> None:
    for r in rows:
        print(r.csv(), flush=True)
