"""Fig 11/12 — fixed vs dynamic process count: parallelism trace, total
admitted budget, throughput (20 participants, one global round)."""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import Row
from repro.core.budget import fedscale_budget_distribution
from repro.core.scheduler import FedHCScheduler
from repro.core.simulator import RoundSimulator, SimClient

WORK_S = 2.0


def run() -> List[Row]:
    budgets = fedscale_budget_distribution(2800, seed=0)
    rng = np.random.default_rng(7)
    idx = rng.choice(len(budgets), size=20, replace=False)
    clients = [SimClient(int(i), budgets[i].budget, WORK_S) for i in idx]
    rows: List[Row] = []
    for mode, par in (("fixed", 3), ("dynamic", 64)):
        sim = RoundSimulator(FedHCScheduler, manager_mode=mode, max_parallel=par)
        res, mgr = sim.run(clients)
        peak_par = max(seg.parallelism for seg in res.timeline)
        rows.append(Row(
            f"fig11.{mode}_processes", res.duration * 1e6,
            {"duration_s": res.duration, "avg_parallelism": res.avg_parallelism(),
             "peak_parallelism": peak_par,
             "avg_admitted_budget": res.avg_admitted_budget(),
             "throughput_clients_per_s": res.throughput},
        ))
    return rows
