"""Fig 11/12 — dynamic process management under pool dynamics: fixed vs
dynamic executor pools, mid-round capacity events driven through the
campaign heap (pod preemption + recovery), and a 2-tenant fabric sharing
one pool (per-tenant utilization + aggregate speedup vs serial)."""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import Row
from repro.core.budget import fedscale_budget_distribution
from repro.core.campaign import CampaignEngine, CapacityEvent
from repro.core.fabric import PoolFabric
from repro.core.scheduler import FedHCScheduler
from repro.core.simulator import RoundSimulator, SimClient

WORK_S = 2.0


def _clients(n: int, seed: int, base: int = 0):
    budgets = fedscale_budget_distribution(2800, seed=0)
    rng = np.random.default_rng(seed)
    idx = rng.choice(len(budgets), size=n, replace=False)
    return [SimClient(base + int(i), budgets[i].budget, WORK_S) for i in idx]


def run() -> List[Row]:
    clients = _clients(20, seed=7)
    rows: List[Row] = []

    # fixed vs dynamic process pools (paper Fig 11)
    for mode, par in (("fixed", 3), ("dynamic", 64)):
        sim = RoundSimulator(FedHCScheduler, manager_mode=mode, max_parallel=par)
        res, mgr = sim.run(clients)
        peak_par = max(seg.parallelism for seg in res.timeline)
        rows.append(Row(
            f"fig11.{mode}_processes", res.duration * 1e6,
            {"duration_s": res.duration, "avg_parallelism": res.avg_parallelism(),
             "peak_parallelism": peak_par,
             "avg_admitted_budget": res.avg_admitted_budget(),
             "throughput_clients_per_s": res.throughput},
        ))

    # capacity events as first-class campaign heap events: the pool loses
    # half its pods mid-round and recovers later (paper Fig 12 regime)
    base = CampaignEngine(FedHCScheduler, max_parallel=64).run_round(clients)
    eng = CampaignEngine(
        FedHCScheduler, max_parallel=64,
        capacity_events=[CapacityEvent(3.0, 50.0, theta=50.0),
                         CapacityEvent(15.0, 100.0, theta=100.0)],
    )
    res = eng.run_round(clients)
    rows.append(Row(
        "fig11.capacity_events_heap", res.duration * 1e6,
        {"duration_s": res.duration,
         "static_duration_s": base.duration,
         "slowdown_vs_static": res.duration / base.duration,
         "capacity_evictions": eng.capacity_evictions,
         "completed": res.completed,
         "utilization": res.utilization()},
    ))

    # 2-tenant fabric: two 60-client campaigns (3 rounds each) sharing one
    # pool vs running them serially on the same capacity
    wa = [_clients(20, seed=11, base=0) for _ in range(3)]
    wb = [_clients(20, seed=13, base=10_000) for _ in range(3)]
    serial = (
        CampaignEngine(FedHCScheduler, max_parallel=64).run_campaign(wa).duration
        + CampaignEngine(FedHCScheduler, max_parallel=64).run_campaign(wb).duration
    )
    fab = PoolFabric(total_slots=64, capacity=100.0, lease_ttl=5.0)
    fab.add_tenant("A", weight=1.0)
    fab.add_tenant("B", weight=1.0)
    shared = fab.run({"A": wa, "B": wb})
    makespan = max(r.duration for r in shared.values())
    rows.append(Row(
        "fig11.fabric_2tenant", makespan * 1e6,
        {"makespan_s": makespan, "serial_total_s": serial,
         "aggregate_speedup": serial / makespan,
         "tenantA_utilization": shared["A"].utilization(),
         "tenantB_utilization": shared["B"].utilization(),
         "tenantA_completed": shared["A"].total_completed,
         "tenantB_completed": shared["B"].total_completed,
         "lease_revocations": fab.arbiter.revocations},
    ))
    return rows
