"""§Roofline — per (arch × shape) terms from the compiled dry-run manifest.

Reads benchmarks/data/roofline_manifest.jsonl (produced by
``python -m repro.launch.dryrun --arch all --shape all --exact --out ...``)
and emits one row per cell: the three roofline terms, dominant bottleneck,
MODEL_FLOPS/HLO_FLOPs, and per-device memory.

Also hosts the ``grouped_matmul`` ragged-groups microbench (the kernel the
batched client executor leans on for heterogeneous waves): per-impl
timing across group-size *distributions* — uniform, skewed, and with
empty groups — plus a correctness check against the per-group dense
reference.  Standalone::

    PYTHONPATH=src python benchmarks/roofline_report.py --quick --check
"""
from __future__ import annotations

import json
import os
import time
from typing import List

from benchmarks.common import Row

MANIFEST = os.path.join(os.path.dirname(__file__), "data", "roofline_manifest.jsonl")


def load_manifest(path: str = MANIFEST) -> List[dict]:
    if not os.path.exists(path):
        return []
    records = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            records[(r.get("arch"), r.get("shape"), r.get("mesh"))] = r  # last wins
    return list(records.values())


def _group_sizes(dist: str, groups: int, rows_per_group: int):
    """Row-count distributions over groups (clients, in FL terms)."""
    import numpy as np

    total = groups * rows_per_group
    if dist == "uniform":
        sizes = np.full(groups, rows_per_group, np.int64)
    elif dist == "skewed":
        # zipf-ish: a few heavy clients carry most rows (FedHC's Non-IID
        # participation regime), rescaled to the same total
        raw = 1.0 / np.arange(1, groups + 1, dtype=np.float64)
        sizes = np.floor(raw / raw.sum() * total).astype(np.int64)
        sizes[0] += total - sizes.sum()
    elif dist == "empty":
        # half the groups contribute nothing this step (sampled-out or
        # zero-example clients) — zero-size groups must be legal
        sizes = np.zeros(groups, np.int64)
        sizes[::2] = 2 * rows_per_group
        sizes[0] += total - sizes.sum()
    else:
        raise ValueError(dist)
    assert sizes.sum() == total and (sizes >= 0).all()
    return sizes


def ragged_groups_rows(quick: bool = False) -> List[Row]:
    """Time ``grouped_matmul`` impls across group-size distributions and
    check each against the per-group dense reference."""
    import jax
    import numpy as np

    from repro.kernels.grouped_matmul.ops import grouped_matmul

    groups, rows_per, d_in, d_out = (16, 8, 64, 32) if quick else (64, 16, 128, 64)
    reps = 3 if quick else 10
    impls = ("ragged", "dense") if quick else ("ragged", "dense", "pallas")
    rng = np.random.default_rng(0)
    out: List[Row] = []
    for dist in ("uniform", "skewed", "empty"):
        sizes = _group_sizes(dist, groups, rows_per)
        m = int(sizes.sum())
        x = rng.normal(size=(m, d_in)).astype(np.float32)
        w = rng.normal(size=(groups, d_in, d_out)).astype(np.float32)
        # reference: per-group numpy matmul over each group's row span
        starts = np.concatenate([[0], np.cumsum(sizes)])
        ref = np.concatenate([
            x[starts[g]:starts[g + 1]] @ w[g] for g in range(groups)
        ]) if m else np.zeros((0, d_out), np.float32)
        gs = jax.numpy.asarray(sizes, jax.numpy.int32)
        for impl in impls:
            fn = jax.jit(lambda a, b, s, _i=impl: grouped_matmul(a, b, s, impl=_i))
            y = jax.block_until_ready(fn(x, w, gs))  # compile + check
            err = float(np.max(np.abs(np.asarray(y) - ref))) if m else 0.0
            best = float("inf")
            for _ in range(reps):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(x, w, gs))
                best = min(best, time.perf_counter() - t0)
            out.append(Row(
                f"roofline.gmm_ragged.{dist}.{impl}", best * 1e6,
                {"groups": groups, "rows": m, "d_in": d_in, "d_out": d_out,
                 "max_abs_err": err, "ok": err <= 1e-3},
            ))
    return out


def run() -> List[Row]:
    from repro.launch.roofline import RooflineTerms

    rows: List[Row] = ragged_groups_rows(quick=True)
    recs = load_manifest()
    if not recs:
        rows.append(Row("roofline.missing_manifest", 0.0,
                        {"hint": "run python -m repro.launch.dryrun --exact --out ..."}))
        return rows
    n_ok = n_skip = n_err = 0
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r.get("mesh", ""))):
        name = f"roofline.{r['arch']}.{r['shape']}.{r.get('mesh','16x16')}"
        if r.get("status") == "skipped":
            n_skip += 1
            rows.append(Row(name, 0.0, {"status": "skipped", "reason": r.get("reason", "")[:60]}))
            continue
        if r.get("status") != "ok":
            n_err += 1
            rows.append(Row(name, 0.0, {"status": r.get("status"), "error": str(r.get("error"))[:80]}))
            continue
        n_ok += 1
        # recompute terms from the raw per-device quantities in the manifest
        terms = RooflineTerms(
            flops=r["flops"], hbm_bytes=r["hbm_bytes"], wire_bytes=r["wire_bytes"],
            chips=r["chips"], model_flops=r["model_flops"],
        )
        rows.append(Row(
            name, max(terms.t_compute, terms.t_memory, terms.t_collective) * 1e6,
            {
                "bottleneck": terms.bottleneck,
                "t_compute_s": terms.t_compute,
                "t_memory_s": terms.t_memory,
                "t_collective_s": terms.t_collective,
                "useful_flops_ratio": round(terms.useful_flops_ratio, 4),
                "roofline_fraction": round(terms.roofline_fraction, 4),
                "GB_per_device": round((r.get("bytes_per_device") or 0) / 1e9, 2),
                "compile_s": r.get("compile_s"),
            },
        ))
    rows.append(Row("roofline.summary", 0.0, {"ok": n_ok, "skipped": n_skip, "errors": n_err}))
    return rows


def main() -> int:
    import argparse

    from benchmarks.common import print_rows

    ap = argparse.ArgumentParser(description="grouped_matmul ragged-groups microbench")
    ap.add_argument("--quick", action="store_true",
                    help="CI scale: smaller shapes, ragged+dense impls only")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero if any impl misses the reference")
    args = ap.parse_args()
    rows = ragged_groups_rows(quick=args.quick)
    print("name,us_per_call,derived")
    print_rows(rows)
    if args.check:
        bad = [r.name for r in rows if not r.derived.get("ok")]
        for name in bad:
            print(f"CORRECTNESS MISS: {name}")
        return 1 if bad else 0
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
