"""§Roofline — per (arch × shape) terms from the compiled dry-run manifest.

Reads benchmarks/data/roofline_manifest.jsonl (produced by
``python -m repro.launch.dryrun --arch all --shape all --exact --out ...``)
and emits one row per cell: the three roofline terms, dominant bottleneck,
MODEL_FLOPS/HLO_FLOPs, and per-device memory.
"""
from __future__ import annotations

import json
import os
from typing import List

from benchmarks.common import Row

MANIFEST = os.path.join(os.path.dirname(__file__), "data", "roofline_manifest.jsonl")


def load_manifest(path: str = MANIFEST) -> List[dict]:
    if not os.path.exists(path):
        return []
    records = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            records[(r.get("arch"), r.get("shape"), r.get("mesh"))] = r  # last wins
    return list(records.values())


def run() -> List[Row]:
    from repro.launch.roofline import RooflineTerms

    rows: List[Row] = []
    recs = load_manifest()
    if not recs:
        rows.append(Row("roofline.missing_manifest", 0.0,
                        {"hint": "run python -m repro.launch.dryrun --exact --out ..."}))
        return rows
    n_ok = n_skip = n_err = 0
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r.get("mesh", ""))):
        name = f"roofline.{r['arch']}.{r['shape']}.{r.get('mesh','16x16')}"
        if r.get("status") == "skipped":
            n_skip += 1
            rows.append(Row(name, 0.0, {"status": "skipped", "reason": r.get("reason", "")[:60]}))
            continue
        if r.get("status") != "ok":
            n_err += 1
            rows.append(Row(name, 0.0, {"status": r.get("status"), "error": str(r.get("error"))[:80]}))
            continue
        n_ok += 1
        # recompute terms from the raw per-device quantities in the manifest
        terms = RooflineTerms(
            flops=r["flops"], hbm_bytes=r["hbm_bytes"], wire_bytes=r["wire_bytes"],
            chips=r["chips"], model_flops=r["model_flops"],
        )
        rows.append(Row(
            name, max(terms.t_compute, terms.t_memory, terms.t_collective) * 1e6,
            {
                "bottleneck": terms.bottleneck,
                "t_compute_s": terms.t_compute,
                "t_memory_s": terms.t_memory,
                "t_collective_s": terms.t_collective,
                "useful_flops_ratio": round(terms.useful_flops_ratio, 4),
                "roofline_fraction": round(terms.roofline_fraction, 4),
                "GB_per_device": round((r.get("bytes_per_device") or 0) / 1e9, 2),
                "compile_s": r.get("compile_s"),
            },
        ))
    rows.append(Row("roofline.summary", 0.0, {"ok": n_ok, "skipped": n_skip, "errors": n_err}))
    return rows
