"""§Perf hillclimbing driver — hypothesis → change → measure → validate.

Three cells chosen from the baseline roofline table (see EXPERIMENTS.md):
  A. kimi-k2-1t-a32b × decode_32k   — worst roofline fraction AND most
     collective-bound (EP weight all-gather per decode step)
  B. whisper-base × prefill_32k     — most collective-bound dense cell
     (TP collectives dwarf a 70M-param model's compute)
  C. gemma3-27b × train_4k          — most representative pod-scale FL silo
     workload (memory-bound)

Each iteration re-lowers/compiles the cell with a config override and
records before/after terms into benchmarks/data/perf_log.jsonl.

Run:  PYTHONPATH=src python -m benchmarks.perf_iterations [--only A,B,C]
"""
import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"

import argparse
import json
import time

OUT = os.path.join(os.path.dirname(__file__), "data", "perf_log.jsonl")


def record(tag, hypothesis, rec):
    entry = {
        "tag": tag,
        "hypothesis": hypothesis,
        "time": time.time(),
        **{k: rec.get(k) for k in (
            "arch", "shape", "status", "flops", "hbm_bytes", "wire_bytes",
            "t_compute_s", "t_memory_s", "t_collective_s", "bottleneck",
            "bytes_per_device", "temp_size_in_bytes", "roofline_fraction",
            "useful_flops_ratio", "compile_s", "error",
        )},
    }
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "a") as f:
        f.write(json.dumps(entry) + "\n")
    keys = ("status", "bottleneck", "t_compute_s", "t_memory_s", "t_collective_s",
            "bytes_per_device", "roofline_fraction")
    print(tag, json.dumps({k: entry.get(k) for k in keys}), flush=True)
    return entry


def run_A():
    """kimi decode: kill the per-step EP weight all-gather."""
    from repro.launch.dryrun import lower_cell

    rec0 = lower_cell("kimi-k2-1t-a32b", "decode_32k", exact=True, verbose=False,
                      overrides={"moe_resident_serve": False})
    record("A0.baseline_gathered_experts",
           "per-step ZeRO-3 all-gather of expert weights dominates decode "
           "collectives (~GBs/step vs KBs of tokens)", rec0)

    rec1 = lower_cell("kimi-k2-1t-a32b", "decode_32k", exact=True, verbose=False,
                      overrides={"moe_resident_serve": True})
    record("A1.resident_experts",
           "keeping experts resident (2-D sharded) and all-gathering the "
           "128 decode tokens instead removes the weight-movement term; "
           "expect t_collective to drop >10x", rec1)

    rec2 = lower_cell("kimi-k2-1t-a32b", "decode_32k", exact=True, verbose=False,
                      overrides={"moe_resident_serve": True, "moe_ep_capacity": 1.0})
    record("A2.decode_capacity_1x",
           "decode batches are small: capacity 2.0 pads the dispatch to 2x "
           "the average load — 1.0 halves grouped-GEMM rows (compute/memory) "
           "at a small drop risk irrelevant for greedy decode", rec2)
    return rec0, rec1, rec2


def run_B():
    """whisper prefill: a 70M model should not be tensor-parallel on 256 chips."""
    from repro.launch.dryrun import lower_cell

    rec0 = lower_cell("whisper-base", "prefill_32k", exact=True, verbose=False,
                      overrides={"use_tp": True})
    record("B0.baseline_tp16",
           "8 heads / d=512 sharded 16-way forces per-layer resharding "
           "collectives that dwarf a 70M-param model's compute", rec0)

    rec1 = lower_cell("whisper-base", "prefill_32k", exact=True, verbose=False,
                      overrides={"use_tp": False})
    record("B1.pure_dp",
           "dropping the model axis (pure DP over batch=32) removes TP "
           "collectives entirely; expect collective term ~0, bottleneck "
           "flips to memory", rec1)

    rec2 = lower_cell("whisper-base", "prefill_32k", exact=True, verbose=False,
                      overrides={"use_tp": False, "act_seq_shard": True})
    record("B2.dp_plus_seq_shard",
           "batch 32 < 256 chips leaves 224 idle under pure DP; sharding "
           "activations over the model axis (sequence dim) re-engages them "
           "for MLP/embedding at the cost of attention boundary collectives",
           rec2)
    return rec0, rec1, rec2


def run_C():
    """gemma3 train: drive the dominant memory term down."""
    from repro.launch.dryrun import lower_cell

    rec0 = lower_cell("gemma3-27b", "train_4k", exact=True, verbose=False)
    record("C0.baseline",
           "memory-bound: remat recompute + oracle-attention probe traffic "
           "+ unchunked-enough loss dominate HBM bytes", rec0)

    rec1 = lower_cell("gemma3-27b", "train_4k", exact=False, verbose=False,
                      overrides={"attn_impl": "reference"})
    record("C1.reference_attention_memory",
           "materializing (S,S) attention scores (the non-flash baseline) "
           "should blow per-device temp memory vs the chunked/Pallas-flash "
           "path — quantifies what the flash kernel saves", rec1)

    rec2 = lower_cell("gemma3-27b", "train_4k", exact=False, verbose=False,
                      overrides={"remat": "dots"})
    record("C2.remat_dots",
           "saving matmul outputs (dots policy) trades recompute for saved "
           "activations: expect temp bytes UP vs full remat — confirms "
           "'full' is the right policy at this batch", rec2)

    rec3 = lower_cell("gemma3-27b", "train_4k", exact=True, verbose=False,
                      overrides={"loss_chunk": 256})
    record("C3.loss_chunk_256",
           "halving the CE chunk halves live logit buffers; expect small "
           "HBM-byte and temp reduction (logits are 512x262k x bf16)", rec3)

    rec4 = lower_cell("gemma3-27b", "train_4k", exact=True, verbose=False,
                      overrides={"act_seq_shard": False})
    record("C4.no_seq_shard(ablate)",
           "turning OFF Megatron-style sequence sharding should RAISE "
           "per-device activation bytes — validates that the optimization "
           "in the baseline is actually earning its keep", rec4)
    return rec0, rec1, rec2, rec3, rec4


def run_A3():
    """kimi decode round 2: the remaining 1.24 s collective is an SPMD
    'involuntary full rematerialization' — K/V head-dim sharding mismatches
    the GQA einsum layout and XLA replicates a 477 MB cache copy per layer."""
    from repro.launch.dryrun import lower_cell

    rec = lower_cell("kimi-k2-1t-a32b", "decode_32k", exact=True, verbose=False,
                     overrides={"moe_resident_serve": True,
                                "decode_cache_seq_shard": True})
    record("A3.splitkv_cache_seq_shard",
           "shard the KV cache on SEQUENCE over the model axis "
           "(split-KV / flash-decoding): the per-layer cache reshard copy "
           "disappears; expect the collective term to drop another ~10x and "
           "memory to drop ~16x (each chip reads 1/16 of the cache)", rec)
    return rec


def run_B2p():
    """whisper round 2: use the idle model axis for activation sequence
    sharding while keeping weights replicated (B2 was a no-op because
    use_tp=False stripped act_seq too — refuted, fixed, re-measured)."""
    from repro.launch.dryrun import lower_cell

    rec = lower_cell("whisper-base", "prefill_32k", exact=True, verbose=False,
                     overrides={"use_tp": False, "act_seq_shard": True})
    record("B2p.dp_plus_seq_shard_fixed",
           "with act_seq kept on the model axis, the residual stream shards "
           "16-way over sequence: per-device activation bytes should drop "
           "~an order of magnitude at the cost of small attention-boundary "
           "collectives", rec)
    return rec


def run_A4():
    """kimi decode round 3: after A3 the memory term (0.272 s — whole-cache
    read per token) is within 1.3x of the collective term; int8 KV halves it."""
    from repro.launch.dryrun import lower_cell

    rec = lower_cell("kimi-k2-1t-a32b", "decode_32k", exact=True, verbose=False,
                     overrides={"moe_resident_serve": True,
                                "decode_cache_seq_shard": True,
                                "kv_cache_quant": True})
    record("A4.int8_kv_cache",
           "decode reads the whole KV cache every step; int8 storage with "
           "per-(b,s,h) scales (KIVI-style, 0.06% logit error measured in "
           "tests) should halve cache bytes -> memory term ~2x down", rec)
    return rec


def run_generalize():
    """Beyond the three assigned cells: the §Perf-A3 split-KV fix applies to
    every GQA arch whose kv-head count (8) does not divide the 16-way model
    axis — measure it on the other collective-bound decode cells."""
    from repro.launch.dryrun import lower_cell

    for arch in ("granite-3-8b", "mistral-nemo-12b", "internvl2-26b"):
        rec = lower_cell(arch, "decode_32k", exact=True, verbose=False,
                         overrides={"decode_cache_seq_shard": True})
        record(f"G.splitkv.{arch}",
               "same GQA reshard pathology as kimi decode (kv=8 on a 16-way "
               "model axis): split-KV sharding should collapse the "
               "collective term here too", rec)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="A,B,C")
    args = ap.parse_args()
    picks = set(args.only.split(","))
    if "A" in picks:
        run_A()
    if "B" in picks:
        run_B()
    if "C" in picks:
        run_C()
    if "A3" in picks:
        run_A3()
    if "B2p" in picks:
        run_B2p()
    if "A4" in picks:
        run_A4()
    if "G" in picks:
        run_generalize()


if __name__ == "__main__":
    main()
