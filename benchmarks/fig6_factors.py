"""Fig 6 — client training time varies with every heterogeneity factor.

Framework-provided runtime (real jitted LSTM train steps, wall-clocked on
this host) divided by the resource budget, exactly the paper's semantics:
smaller budget / longer sequences / more layers => longer client time;
larger batch => shorter per-sample time.
"""
from __future__ import annotations

from typing import List

import jax

from benchmarks.common import Row
from repro.core.runtime import MeasuredRuntime
from repro.fed.client import make_small_step
from repro.models.small import SmallModelConfig, init_small
from repro.optim.optimizers import sgd

_BASE = dict(kind="lstm", n_classes=2, hidden=64, n_layers=2, vocab_size=512)


def _time(rt: MeasuredRuntime, mcfg: SmallModelConfig, batch_size: int, seq_len: int,
          n_batches: int = 8) -> float:
    opt = sgd(0.1)
    step = make_small_step(mcfg, opt)
    params = init_small(jax.random.PRNGKey(0), mcfg)
    opt_state = opt.init(params)
    x = jax.random.randint(jax.random.PRNGKey(1), (batch_size, seq_len), 0, mcfg.vocab_size)
    y = jax.random.randint(jax.random.PRNGKey(2), (batch_size,), 0, mcfg.n_classes)
    key = (mcfg.n_layers, seq_len, batch_size)
    return rt.seconds_at_full(
        key, lambda p, o, b: step(p, o, b, p)[0], (params, opt_state, {"x": x, "y": y}),
        n_steps=n_batches,
    )


def run() -> List[Row]:
    rt = MeasuredRuntime()
    rows: List[Row] = []
    base = SmallModelConfig(**_BASE)
    t_base = _time(rt, base, batch_size=32, seq_len=64)

    for budget in (100, 50, 25, 10):
        t = t_base / (budget / 100.0)
        rows.append(Row(f"fig6.budget_{budget}", t * 1e6, {"seconds": t, "budget": budget}))
    for seq in (16, 64, 128):
        t = _time(rt, base, batch_size=32, seq_len=seq)
        rows.append(Row(f"fig6.seq_{seq}", t * 1e6, {"seconds": t}))
    for layers in (1, 2, 4):
        t = _time(rt, base.replace(n_layers=layers), batch_size=32, seq_len=64)
        rows.append(Row(f"fig6.layers_{layers}", t * 1e6, {"seconds": t}))
    for bs in (16, 32, 64):
        # same total samples: fewer steps at bigger batch
        t = _time(rt, base, batch_size=bs, seq_len=64, n_batches=256 // bs)
        rows.append(Row(f"fig6.batch_{bs}", t * 1e6, {"seconds": t, "samples": 256}))
    return rows
