"""Wire codec benchmark: v1 JSON+base64 vs v2 binary tensor framing.

Measures encode+decode throughput (MB/s of raw delta bytes) and
bytes-on-wire for one ``UPLOAD`` envelope across
``{fp32, bf16, int8, topk} x {small CNN, LM-sized}`` deltas, in both wire
protocol versions (plus the v2 deflate variant), and emits the repo's
first pinned perf-trajectory file, ``BENCH_wire.json``.

The *v1 path* for each cell is what PR 4 actually shipped: tensors ride
as base64 inside JSON (~4/3 inflation), and compressed deltas are
re-inflated to fp32 before serialization (the old
``ControlPlaneMirror``/trainer behavior).  The *v2 path* is the codec
this PR introduces: raw binary segments after a compact JSON header,
with int8/topk compression transmitted natively and optional per-segment
deflate.

Headline criteria (asserted by ``--check``, run by the CI wire-bench job):

* ``fp32_reduction``  >= 3.5x — v1 fp32 JSON vs the combined v2 path for
  fp32 deltas (base64->raw ~1.33x, fp32->bf16 native wire cast ~2x,
  deflate on the LM delta's untouched embedding rows makes up the rest);
* ``int8_reduction``  >= 10x — v1 (int8 re-inflated to fp32 JSON) vs v2
  native int8+deflate;
* ``throughput_speedup`` >= 2x — encode+decode MB/s, v2 raw fp32 vs v1
  fp32, on the LM-sized delta.  The floor was 5x before the
  fault-tolerance work; v2 frames now carry a crc32 over the tensor
  blob (the corruption detector the chaos tests rely on), which costs
  ~1 GB/s on each side of the wire and is priced into the floor;
* ``wal_overhead`` >= 2x — the ingest path's per-upload cost (decode +
  exact-accumulator fold) over the write-ahead journal's per-upload
  append cost: durability must stay well under half the work the server
  already does per accepted upload (``docs/wire-protocol.md`` § 10).

The LM delta is realistic for FL local training: only a small fraction of
embedding rows are touched by a client's local steps (the rest are
exactly zero), while attention/MLP matrices are dense.

Usage::

    PYTHONPATH=src python benchmarks/wire_codec.py            # full run
    PYTHONPATH=src python benchmarks/wire_codec.py --quick --check   # CI
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Any, Dict, List, Tuple

import numpy as np

from repro.fed.compression import compress_tree, decompress_tree
from repro.fed.transport import (
    Message,
    MsgType,
    decode_wire_body,
    encode_envelope_wire,
    parse_envelope,
)

_LEN_PREFIX = 4


# --------------------------------------------------------------------------
# Delta construction
# --------------------------------------------------------------------------


def build_cnn_delta(rng: np.random.Generator, scale: float = 1.0) -> Dict[str, Any]:
    """Small-CNN-shaped dense delta (conv + dense towers), ~200 KB fp32."""
    h = max(8, int(32 * scale))
    return {
        "conv1": {"w": rng.normal(0, 1e-2, (3, 3, 1, h)).astype(np.float32),
                  "b": rng.normal(0, 1e-2, (h,)).astype(np.float32)},
        "conv2": {"w": rng.normal(0, 1e-2, (3, 3, h, 2 * h)).astype(np.float32),
                  "b": rng.normal(0, 1e-2, (2 * h,)).astype(np.float32)},
        "dense": {"w": rng.normal(0, 1e-2, (2 * h * 49, 64)).astype(np.float32),
                  "b": rng.normal(0, 1e-2, (64,)).astype(np.float32)},
        "head": {"w": rng.normal(0, 1e-2, (64, 10)).astype(np.float32),
                 "b": rng.normal(0, 1e-2, (10,)).astype(np.float32)},
    }


def build_lm_delta(rng: np.random.Generator, scale: float = 1.0,
                   touched_frac: float = 0.05) -> Dict[str, Any]:
    """LM-shaped delta: a large embedding table where only
    ``touched_frac`` of the rows are nonzero (rows for tokens a client's
    local batches never saw get zero gradient), plus dense
    attention/MLP blocks."""
    vocab = max(256, int(16_384 * scale))
    d = max(64, int(320 * scale))
    embed = np.zeros((vocab, d), np.float32)
    touched = rng.choice(vocab, size=max(1, int(vocab * touched_frac)),
                         replace=False)
    embed[touched] = rng.normal(0, 1e-2, (len(touched), d)).astype(np.float32)
    layers = {}
    for i in range(2):
        layers[f"layer{i}"] = {
            "attn": {
                "wq": rng.normal(0, 1e-2, (d, d)).astype(np.float32),
                "wk": rng.normal(0, 1e-2, (d, d)).astype(np.float32),
                "wv": rng.normal(0, 1e-2, (d, d)).astype(np.float32),
                "wo": rng.normal(0, 1e-2, (d, d)).astype(np.float32),
            },
            "mlp": {
                "up": rng.normal(0, 1e-2, (d, 4 * d)).astype(np.float32),
                "down": rng.normal(0, 1e-2, (4 * d, d)).astype(np.float32),
            },
        }
    return {"embed": embed, **layers}


def delta_nbytes(delta: Any) -> int:
    import jax

    return sum(np.asarray(l).nbytes for l in jax.tree_util.tree_leaves(delta))


def _cast_tree(delta: Any, dtype) -> Any:
    import jax

    return jax.tree_util.tree_map(lambda l: np.asarray(l).astype(dtype), delta)


# --------------------------------------------------------------------------
# One measurement
# --------------------------------------------------------------------------


def _time_codec(payload: Dict[str, Any], version: int, deflate: bool,
                reps: int) -> Tuple[int, float, float]:
    """-> (framed bytes, encode seconds/op, decode seconds/op)."""
    msg = Message(MsgType.UPLOAD, 0, payload)
    enc = encode_envelope_wire(1, 0, msg, version=version, deflate=deflate)
    body = enc.data[_LEN_PREFIX:]
    t_enc = []
    for _ in range(reps):
        t0 = time.perf_counter()
        encode_envelope_wire(1, 0, msg, version=version, deflate=deflate)
        t_enc.append(time.perf_counter() - t0)
    t_dec = []
    for _ in range(reps):
        t0 = time.perf_counter()
        parse_envelope(decode_wire_body(body)[0])
        t_dec.append(time.perf_counter() - t0)
    return len(enc.data), min(t_enc), min(t_dec)


def bench_cell(name: str, delta: Dict[str, Any], method: str,
               reps: int) -> Dict[str, Any]:
    """Bench one (delta, method) cell across wire paths."""
    raw = delta_nbytes(delta)

    if method == "fp32":
        v1_payload = {"delta": delta, "n": 16, "round": 0}
        v2_payload = v1_payload
        # the combined fp32 path the tentpole names: bf16 native wire cast
        v2_alt = {"delta": _cast_tree(delta, "bfloat16"), "n": 16, "round": 0}
        alt_name = "v2_bf16"
    elif method == "bf16":
        bf = _cast_tree(delta, "bfloat16")
        v1_payload = {"delta": bf, "n": 16, "round": 0}
        v2_payload = v1_payload
        v2_alt, alt_name = None, None
    else:   # int8 | topk
        comp = compress_tree(delta, method, seed=0)
        # v1 shipped the *dequantized* fp32 tensors (re-inflation)
        v1_payload = {"delta": decompress_tree(comp), "n": 16, "round": 0}
        # v2 ships the compressed tree natively
        v2_payload = {"delta": comp, "n": 16, "round": 0}
        v2_alt, alt_name = None, None

    out: Dict[str, Any] = {"cell": name, "method": method, "raw_bytes": raw}
    b1, e1, d1 = _time_codec(v1_payload, 1, False, reps)
    out["v1"] = {"wire_bytes": b1, "encode_s": e1, "decode_s": d1,
                 "enc_mbps": raw / e1 / 1e6, "dec_mbps": raw / d1 / 1e6}
    b2, e2, d2 = _time_codec(v2_payload, 2, False, reps)
    out["v2"] = {"wire_bytes": b2, "encode_s": e2, "decode_s": d2,
                 "enc_mbps": raw / e2 / 1e6, "dec_mbps": raw / d2 / 1e6}
    bz, ez, dz = _time_codec(v2_payload, 2, True, reps)
    out["v2_deflate"] = {"wire_bytes": bz, "encode_s": ez, "decode_s": dz}
    if v2_alt is not None:
        ba, ea, da = _time_codec(v2_alt, 2, False, reps)
        bzz, ezz, dzz = _time_codec(v2_alt, 2, True, reps)
        out[alt_name] = {"wire_bytes": ba, "encode_s": ea, "decode_s": da}
        out[alt_name + "_deflate"] = {"wire_bytes": bzz, "encode_s": ezz,
                                      "decode_s": dzz}
    return out


# --------------------------------------------------------------------------
# Hierarchical fan-in: tree vs flat ingest throughput
# --------------------------------------------------------------------------


def _fanin_fold(bodies: List[bytes]) -> Dict[str, Any]:
    """One aggregator node's round: decode each session's UPLOAD frame and
    fold it into an exact accumulator; return the PARTIAL_SUM payload."""
    from repro.fed.hier import ExactAccumulator

    acc = ExactAccumulator()
    for body in bodies:
        _seq, _ack, msg = parse_envelope(decode_wire_body(body)[0])
        acc.fold(msg.payload["delta"], int(msg.payload["n"]))
    return acc.to_payload()


def bench_fanin(sessions: int, n_leaves: int, reps: int,
                shape: Tuple[int, int]) -> Dict[str, Any]:
    """Fan-in cell: ``sessions`` concurrent client sessions' uploads
    ingested by one flat node vs a tree of ``n_leaves`` leaves + root.

    In deployment every aggregator node is its own host, so the tree's
    round latency is its **critical path**: the slowest leaf's ingest
    plus the root's merge+finalize.  To keep the metric independent of
    how many cores this bench box happens to have, each node's work is
    measured serially at full core and the tree time is
    ``max(leaf times) + root time`` — the wall clock a real multi-host
    tree would see.  Equal total clients and identical wire bytes on
    both sides; both paths must finalize to bit-identical params
    (asserted here, every run)."""
    from repro.fed.hier import ExactAccumulator, params_digest

    rng = np.random.default_rng(7)
    bodies = []
    for cid in range(sessions):
        delta = {"w": rng.normal(0, 1e-2, shape).astype(np.float32)}
        msg = Message(MsgType.UPLOAD, cid,
                      {"delta": delta, "n": 1 + cid % 7, "round": 0})
        bodies.append(encode_envelope_wire(1, 0, msg, version=2)
                      .data[_LEN_PREFIX:])
    shares = [bodies[i::n_leaves] for i in range(n_leaves)]

    _fanin_fold(shares[0])                  # warm caches once
    flat_s, tree_s = [], []
    tree_digest = flat_digest = None
    for _ in range(reps):
        t0 = time.perf_counter()
        flat = ExactAccumulator.from_payload(_fanin_fold(bodies))
        flat_digest = params_digest(flat.finalize_mean())
        flat_s.append(time.perf_counter() - t0)

        leaf_times, partials = [], []
        for share in shares:                # one node at a time, full core
            t0 = time.perf_counter()
            partials.append(_fanin_fold(share))
            leaf_times.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        root = ExactAccumulator()
        for p in partials:
            root.merge(ExactAccumulator.from_payload(p))
        tree_digest = params_digest(root.finalize_mean())
        root_time = time.perf_counter() - t0
        tree_s.append(max(leaf_times) + root_time)
    assert tree_digest == flat_digest, "fan-in bench: tree != flat"
    fs, ts = min(flat_s), min(tree_s)
    return {
        "cell": "fanin", "method": "fp32", "sessions": sessions,
        "leaves": n_leaves, "delta_bytes": int(np.prod(shape)) * 4,
        "flat_s": fs, "tree_s": ts, "speedup": fs / ts,
        "flat_sessions_per_s": sessions / fs,
        "tree_sessions_per_s": sessions / ts,
    }


# --------------------------------------------------------------------------
# WAL durability tax: journaling an accepted upload vs handling it
# --------------------------------------------------------------------------


def bench_wal(uploads: int, reps: int, shape: Tuple[int, int]) -> Dict[str, Any]:
    """Durability cell: the write-ahead journal's per-upload cost next to
    the work the server was already doing for that upload (decode the
    frame + fold into the exact accumulator).

    ``wal_overhead`` is handle-time / append-time — bigger is better: a
    ratio of R means journaling adds ~1/R of the ingest path's cost, so
    crash-restart durability rides along nearly free.  Every rep also
    replays the journal through :func:`repro.fed.wal.recover` and asserts
    the re-folded digest is bit-identical to the direct fold — the same
    guarantee the crash-restart tests make, measured at bench scale."""
    import os
    import tempfile

    from repro.fed.hier import ExactAccumulator, params_digest
    from repro.fed.wal import RoundJournal, recover

    rng = np.random.default_rng(11)
    payloads, bodies = [], []
    for cid in range(uploads):
        delta = {"w": rng.normal(0, 1e-2, shape).astype(np.float32)}
        payload = {"delta": delta, "n": 1 + cid % 7, "round": 0}
        payloads.append(payload)
        msg = Message(MsgType.UPLOAD, cid, payload)
        bodies.append(encode_envelope_wire(1, 0, msg, version=2)
                      .data[_LEN_PREFIX:])

    handle_s, append_s, replay_s = [], [], []
    wal_bytes = 0
    digest = None
    with tempfile.TemporaryDirectory() as td:
        for r in range(reps):
            t0 = time.perf_counter()
            acc = ExactAccumulator()
            for body in bodies:
                _seq, _ack, msg = parse_envelope(decode_wire_body(body)[0])
                acc.fold(msg.payload["delta"], int(msg.payload["n"]))
            handle_s.append(time.perf_counter() - t0)
            digest = params_digest(acc.finalize_mean())

            path = os.path.join(td, f"wal_{r}.bin")
            j = RoundJournal(path)
            j.open_round(0)
            t0 = time.perf_counter()
            for cid, payload in enumerate(payloads):
                j.upload(cid, payload)
            append_s.append(time.perf_counter() - t0)
            wal_bytes = j.bytes_written
            j.close()

            t0 = time.perf_counter()
            rec = recover(path)
            replay = ExactAccumulator()
            for cid, p in rec.rounds[0].uploads:
                replay.fold(p["delta"], int(p["n"]))
            replay_digest = params_digest(replay.finalize_mean())
            replay_s.append(time.perf_counter() - t0)
            assert rec.records == uploads + 1, rec.records
            assert replay_digest == digest, "wal bench: replay != direct"
    hs, js, rs = min(handle_s), min(append_s), min(replay_s)
    return {
        "cell": "wal", "method": "fp32", "uploads": uploads,
        "delta_bytes": int(np.prod(shape)) * 4,
        "handle_s": hs, "append_s": js, "replay_s": rs,
        "wal_bytes_per_upload": wal_bytes / max(1, uploads),
        "wal_overhead": hs / js,
    }


# --------------------------------------------------------------------------
# Driver
# --------------------------------------------------------------------------


def run(quick: bool = False) -> Dict[str, Any]:
    rng = np.random.default_rng(0)
    scale = 0.25 if quick else 1.0
    reps = 2 if quick else 3
    deltas = {
        "cnn": build_cnn_delta(rng, scale=1.0),   # already small
        "lm": build_lm_delta(rng, scale=scale),
    }
    cells: List[Dict[str, Any]] = []
    for name, delta in deltas.items():
        for method in ("fp32", "bf16", "int8", "topk"):
            cell = bench_cell(name, delta, method, reps)
            cells.append(cell)
            print(f"{name:>4s} {method:>5s}: raw={cell['raw_bytes']:>10d}B  "
                  f"v1={cell['v1']['wire_bytes']:>10d}B  "
                  f"v2={cell['v2']['wire_bytes']:>10d}B  "
                  f"v2+z={cell['v2_deflate']['wire_bytes']:>10d}B  "
                  f"v1 enc {cell['v1']['enc_mbps']:7.1f} MB/s  "
                  f"v2 enc {cell['v2']['enc_mbps']:7.1f} MB/s", flush=True)

    fanin = bench_fanin(sessions=1024 if quick else 2048, n_leaves=8,
                        reps=reps, shape=(64, 64))
    cells.append(fanin)
    print(f"fanin: {fanin['sessions']} sessions, {fanin['leaves']} leaves  "
          f"flat={fanin['flat_s'] * 1e3:7.1f} ms  "
          f"tree={fanin['tree_s'] * 1e3:7.1f} ms  "
          f"speedup={fanin['speedup']:.2f}x", flush=True)

    wal = bench_wal(uploads=512 if quick else 1024, reps=reps,
                    shape=(64, 64))
    cells.append(wal)
    print(f"  wal: {wal['uploads']} uploads  "
          f"handle={wal['handle_s'] * 1e3:7.1f} ms  "
          f"append={wal['append_s'] * 1e3:7.1f} ms  "
          f"replay={wal['replay_s'] * 1e3:7.1f} ms  "
          f"overhead ratio={wal['wal_overhead']:.2f}x", flush=True)

    by_key = {(c["cell"], c["method"]): c for c in cells}
    lm_fp32 = by_key[("lm", "fp32")]
    lm_int8 = by_key[("lm", "int8")]
    v1_enc_dec = lm_fp32["v1"]["encode_s"] + lm_fp32["v1"]["decode_s"]
    v2_enc_dec = lm_fp32["v2"]["encode_s"] + lm_fp32["v2"]["decode_s"]
    headline = {
        # combined fp32 path: base64->raw + fp32->bf16 native + deflate
        "fp32_reduction": lm_fp32["v1"]["wire_bytes"]
        / lm_fp32["v2_bf16_deflate"]["wire_bytes"],
        "fp32_raw_reduction": lm_fp32["v1"]["wire_bytes"]
        / lm_fp32["v2"]["wire_bytes"],
        "int8_reduction": lm_int8["v1"]["wire_bytes"]
        / lm_int8["v2_deflate"]["wire_bytes"],
        "throughput_speedup": v1_enc_dec / v2_enc_dec,
        "lm_raw_mb": lm_fp32["raw_bytes"] / 1e6,
        # hierarchical fan-in: tree of leaf processes vs one flat node,
        # equal clients, 128 concurrent sessions on the flat node
        "tree_fanin": fanin["speedup"],
        # durability tax: ingest-path cost per upload over journal-append
        # cost per upload (bigger = cheaper WAL)
        "wal_overhead": wal["wal_overhead"],
    }
    print("\nheadline (LM-sized delta):")
    for k, v in headline.items():
        print(f"  {k:>20s}: {v:8.2f}")
    return {
        "bench": "wire_codec",
        "quick": quick,
        "cells": cells,
        "headline": headline,
        # throughput floor re-based 5.0 -> 2.0 when v2 frames grew the
        # anti-corruption blob crc (see module docstring)
        "thresholds": {"fp32_reduction": 3.5, "int8_reduction": 10.0,
                       "throughput_speedup": 2.0, "tree_fanin": 2.0,
                       "wal_overhead": 2.0},
    }


def check(report: Dict[str, Any]) -> List[str]:
    fails = []
    for key, floor in report["thresholds"].items():
        got = report["headline"][key]
        if got < floor:
            fails.append(f"{key} = {got:.2f} < required {floor}")
    return fails


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI scale: ~2 MB LM delta, 2 reps")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero if a headline threshold is missed")
    ap.add_argument("--out", default="BENCH_wire.json")
    args = ap.parse_args()
    report = run(quick=args.quick)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"\nwrote {args.out}")
    if args.check:
        fails = check(report)
        for f_ in fails:
            print(f"THRESHOLD MISS: {f_}")
        return 1 if fails else 0
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
