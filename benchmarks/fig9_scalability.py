"""Fig 9 — scalability: round duration vs participants, FedHC vs the
resource-constrained FedScale-like baseline (greedy + fixed parallelism).

2800 clients with the FedScale-speed-derived budget distribution (Fig 9a);
participants per round swept 100 → 2000.  The paper reports 2.75× at 2000.
Beyond the paper: a sequential multi-round *campaign* (continuous clock,
availability churn) per scheduler, the regime FedML-Parrot/BouquetFL argue
actually separates heterogeneity-aware schedulers from greedy ones.
"""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import Row
from repro.core.budget import fedscale_budget_distribution
from repro.core.campaign import AvailabilityTrace, CampaignEngine
from repro.core.scheduler import FedHCScheduler, GreedyScheduler
from repro.core.simulator import RoundSimulator, SimClient

POOL = 2800
WORK_S = 2.0  # seconds-at-full per client (500 batches of 64 in the paper)


def _clients(n: int, seed: int) -> List[SimClient]:
    budgets = fedscale_budget_distribution(POOL, seed=0)
    rng = np.random.default_rng(seed)
    idx = rng.choice(POOL, size=n, replace=False)
    rng2 = np.random.default_rng(seed + 1)
    # mild workload heterogeneity on top of budgets (data volume spread)
    return [
        SimClient(int(i), budgets[i].budget, WORK_S * float(rng2.uniform(0.5, 1.5)))
        for i in idx
    ]


def run() -> List[Row]:
    rows: List[Row] = []
    dist = fedscale_budget_distribution(POOL, seed=0)
    vals = np.array([c.budget for c in dist])
    rows.append(Row("fig9a.budget_distribution", 0.0, {
        "clients": POOL, "p10": float(np.percentile(vals, 10)),
        "median": float(np.median(vals)), "p90": float(np.percentile(vals, 90)),
    }))

    for n in (100, 500, 1000, 2000):
        clients = _clients(n, seed=n)
        fedhc = RoundSimulator(FedHCScheduler, manager_mode="dynamic", max_parallel=64)
        base = RoundSimulator(GreedyScheduler, manager_mode="fixed", max_parallel=4)
        rf, _ = fedhc.run(clients)
        rb, _ = base.run(clients)
        speedup = rb.duration / rf.duration
        rows.append(Row(
            f"fig9c.participants_{n}", rf.duration * 1e6,
            {"fedhc_s": rf.duration, "fedscale_like_s": rb.duration,
             "speedup": speedup, "fedhc_util": rf.utilization(),
             "baseline_util": rb.utilization()},
        ))

    # campaign-scale: 20 sequential rounds of 500 participants with
    # availability churn, one continuous clock per scheduler
    pool = fedscale_budget_distribution(POOL, seed=0)
    rng = np.random.default_rng(7)
    rounds = []
    for _ in range(20):
        idx = rng.choice(POOL, size=500, replace=False)
        rounds.append([
            SimClient(int(i), pool[i].budget, WORK_S * float(rng.uniform(0.5, 1.5)))
            for i in idx
        ])
    # the trace horizon must cover the whole campaign (~66k simulated s),
    # otherwise tracked clients go permanently offline once it ends and
    # their client-rounds silently vanish from the speedup comparison
    trace = AvailabilityTrace.periodic(
        list(range(POOL // 4)), period=600.0, duty=0.7, horizon=150_000.0, seed=5)
    camp = {}
    for name, sched in (("fedhc", FedHCScheduler), ("greedy", GreedyScheduler)):
        eng = CampaignEngine(sched, max_parallel=64, availability=trace)
        camp[name] = eng.run_campaign(rounds)
    speedup = camp["greedy"].duration / camp["fedhc"].duration
    rows.append(Row("fig9.campaign_20x500_churn", camp["fedhc"].duration * 1e6, {
        "fedhc_s": camp["fedhc"].duration,
        "greedy_s": camp["greedy"].duration,
        "speedup": speedup,
        "fedhc_completed": camp["fedhc"].total_completed,
        "greedy_completed": camp["greedy"].total_completed,
        "fedhc_evictions": camp["fedhc"].churn_evictions,
    }))

    # Fig 9d — convergence improves with participants per round
    from repro.core.budget import uniform_budgets
    from repro.fed.trainer import FedConfig, FederatedTrainer, build_fl_clients
    from repro.models.small import SmallModelConfig

    mcfg = SmallModelConfig(kind="mlp", n_classes=10, hidden=32, n_layers=2,
                            image_size=28, channels=1)
    budgets = uniform_budgets([10, 25, 40, 55, 70, 85, 100, 30, 60, 90, 15, 45])
    for n_part in (2, 5, 10):
        clients, test = build_fl_clients(
            mcfg, budgets, "femnist", n_samples=1800, batch_size=16,
            n_batches=4, seed=3,
        )
        for c in clients:
            c.data.y = c.data.y % 10
        test["y"] = test["y"] % 10
        fed = FedConfig(rounds=8, participants_per_round=n_part, local_steps=4,
                        learning_rate=0.2, seed=3)
        hist = FederatedTrainer(mcfg, clients, fed, test_batch=test).run()
        rows.append(Row(
            f"fig9d.participants_{n_part}", hist[-1]["sim_clock"] * 1e6,
            {"final_acc": hist[-1]["test_acc"], "rounds": 8},
        ))
    return rows
