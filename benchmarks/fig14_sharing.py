"""Fig 14 — resource sharing: hard margin (θ=100) vs soft margin (θ=150),
10 participants per round: total admitted budget, parallelism, throughput,
and the per-client slowdown distribution under contention."""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import Row
from repro.core.budget import fedscale_budget_distribution
from repro.core.scheduler import FedHCScheduler
from repro.core.sharing import slowdown
from repro.core.simulator import RoundSimulator, SimClient

WORK_S = 2.0


def run() -> List[Row]:
    budgets = fedscale_budget_distribution(2800, seed=0)
    rng = np.random.default_rng(3)
    idx = rng.choice(len(budgets), size=10, replace=False)
    clients = [SimClient(int(i), budgets[i].budget, WORK_S) for i in idx]
    rows: List[Row] = []
    res_by = {}
    for name, theta in (("hard_100", 100.0), ("soft_150", 150.0)):
        sim = RoundSimulator(FedHCScheduler, theta=theta, max_parallel=64)
        res, _ = sim.run(clients)
        res_by[name] = res
        rows.append(Row(
            f"fig14.{name}", res.duration * 1e6,
            {"duration_s": res.duration,
             "avg_admitted_budget": res.avg_admitted_budget(),
             "avg_parallelism": res.avg_parallelism(),
             "throughput_clients_per_s": res.throughput},
        ))
    # per-client slowdown when everything the soft margin admits runs at once
    active = [(c.client_id, c.budget) for c in clients]
    sd = slowdown(active)
    rows.append(Row(
        "fig14.slowdown", 0.0,
        {"max_slowdown": max(sd.values()), "mean_slowdown": float(np.mean(list(sd.values()))),
         "small_clients_unaffected": all(
             v <= 1.01 for cid, v in sd.items()
             if dict(active)[cid] <= 20.0)},
    ))
    return rows
