"""Client-batched execution benchmark: sequential vs one-program waves.

Times a full COLLECT wave — every participant's local training for one
round — on the sequential path (one jitted step per client per batch,
the pre-batching trainer behaviour) vs ``repro.fed.batch_exec``'s
``BatchedExecutor`` (the whole wave as ONE compiled program), at
8 / 64 / 256 clients, plus a ragged cell where per-client batch sizes
differ and the wave runs through the ``grouped_matmul`` kernel path.

Both paths are fed *twin worlds* built from the same seeds, so the
per-client updated params must match: bit-identical on the dense vmap
path, allclose (documented tolerance, matmul summation order) on the
ragged path.  The params check is part of ``--check``, not just the
speedup floors.

The win on a 1-core CPU host is dispatch amortization: the sequential
path pays Python + jit-call overhead ``clients x steps`` times per
round, the batched path once per wave.  (On real accelerator meshes the
wave additionally spreads over devices via ``shard_map``.)  The model is
deliberately small — FL client workloads are edge-device sized, which is
exactly the dispatch-bound regime FL simulators live in (FedML Parrot
makes the same observation).

Headline criteria (asserted by ``--check``, run by the CI clients-bench
job):

* ``speedup_64``  >= 5.0 full / >= 2.0 quick — wall-clock, one 64-client
  round, batched vs sequential (quick floor is lower: CI runners are
  shared and noisy, and quick mode runs fewer local steps so fixed
  per-wave costs amortize less);
* ``ragged_speedup_64`` >= 1.5 — the grouped-matmul ragged wave must
  also beat sequential, not just the uniform vmap wave;
* ``params_max_abs_diff`` <= 1e-5 — batched per-client updated params
  match sequential per-client params across every cell;
* ``cache_hit_waves`` — every wave after a cell's first must hit the
  compiled-program cache (no silent per-wave recompilation).

Usage::

    PYTHONPATH=src python benchmarks/client_batch.py           # full run
    PYTHONPATH=src python benchmarks/client_batch.py --quick --check  # CI
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Any, Dict, List

import numpy as np

import jax

from repro.core.budget import WorkloadSpec
from repro.data.pipeline import ClientDataset
from repro.fed.batch_exec import BatchedExecutor
from repro.fed.client import FLClient, make_small_step, step_cache_stats
from repro.models.small import SmallModelConfig, init_small
from repro.optim.optimizers import make_optimizer

MCFG = SmallModelConfig(kind="mlp", hidden=16, n_layers=2, image_size=8,
                        channels=1, n_classes=10)


def build_world(n_clients: int, batch_sizes, seed: int):
    """A fresh FL world: per-client shards + the shared global params.
    Called twice with the same seed per measurement so the sequential and
    batched runs consume identical data-pipeline RNG state."""
    rng = np.random.default_rng(seed)
    clients = []
    for i in range(n_clients):
        bs = batch_sizes[i % len(batch_sizes)]
        n = max(4 * bs, 8)
        x = rng.normal(size=(n, MCFG.image_size, MCFG.image_size,
                             MCFG.channels)).astype(np.float32)
        y = rng.integers(0, MCFG.n_classes, size=n).astype(np.int32)
        clients.append(FLClient(i, 100.0, ClientDataset(x, y, bs, seed=seed + i),
                                WorkloadSpec()))
    params = init_small(jax.random.PRNGKey(seed), MCFG)
    return clients, params


def run_sequential(clients, params, opt, steps: int):
    step = make_small_step(MCFG, opt, 0.0)
    return [c.train_local(params, step, opt, n_steps=steps) for c in clients]


def _max_abs_diff(res_a, res_b) -> float:
    return max(
        float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
        for (da, _, _), (db, _, _) in zip(res_a, res_b)
        for a, b in zip(jax.tree.leaves(da), jax.tree.leaves(db))
    )


def bench_cell(name: str, n_clients: int, batch_sizes, steps: int,
               reps: int, opt) -> Dict[str, Any]:
    """One (cell, client-count) measurement: best-of-``reps`` wall time
    per path, params-match on the last rep, executor cache stats."""
    ex = BatchedExecutor(MCFG, opt, 0.0)
    # warmup: compile both paths outside the timed region
    cl, params = build_world(n_clients, batch_sizes, seed=0)
    run_sequential(cl, params, opt, steps)
    cl, params = build_world(n_clients, batch_sizes, seed=0)
    ex.run_wave(params, cl, steps, round_idx=0)

    best_seq = best_bat = float("inf")
    seq_res = bat_res = None
    for rep in range(reps):
        cl, params = build_world(n_clients, batch_sizes, seed=1 + rep)
        t0 = time.perf_counter()
        seq_res = run_sequential(cl, params, opt, steps)
        jax.block_until_ready([d for d, _, _ in seq_res])
        best_seq = min(best_seq, time.perf_counter() - t0)

        cl, params = build_world(n_clients, batch_sizes, seed=1 + rep)
        t0 = time.perf_counter()
        bat_res = ex.run_wave(params, cl, steps, round_idx=1 + rep)
        best_bat = min(best_bat, time.perf_counter() - t0)

    stats = ex.stats.as_dict()
    return {
        "cell": name,
        "clients": n_clients,
        "steps": steps,
        "batch_sizes": sorted(set(batch_sizes)),
        "mode": ex.last_wave.get("mode"),
        "seq_s": best_seq,
        "bat_s": best_bat,
        "speedup": best_seq / best_bat,
        "params_max_abs_diff": _max_abs_diff(seq_res, bat_res),
        "waves": stats["waves"],
        "compiles": stats["compiles"],
        "cache_hits": stats["cache_hits"],
    }


def run(quick: bool = False) -> Dict[str, Any]:
    steps = 10 if quick else 25
    reps = 2 if quick else 3
    opt = make_optimizer("sgd", 0.05)
    cells: List[Dict[str, Any]] = []
    plan = [
        ("dense_8", 8, [4]),
        ("dense_64", 64, [4]),
        ("dense_256", 256, [4]),
        ("ragged_64", 64, [2, 4, 6, 8]),
    ]
    for name, n, bss in plan:
        cell = bench_cell(name, n, bss, steps, reps, opt)
        cells.append(cell)
        print(f"{name:>10s}: C={n:3d} mode={cell['mode']:>6s}  "
              f"seq {cell['seq_s']*1e3:7.1f}ms  bat {cell['bat_s']*1e3:6.1f}ms  "
              f"{cell['speedup']:5.2f}x  max|d|={cell['params_max_abs_diff']:.1e}  "
              f"compiles={cell['compiles']} hits={cell['cache_hits']}",
              flush=True)

    by = {c["cell"]: c for c in cells}
    headline = {
        "speedup_8": by["dense_8"]["speedup"],
        "speedup_64": by["dense_64"]["speedup"],
        "speedup_256": by["dense_256"]["speedup"],
        "ragged_speedup_64": by["ragged_64"]["speedup"],
        "params_max_abs_diff": max(c["params_max_abs_diff"] for c in cells),
        # waves past each cell's first (the warmup compile) must hit the
        # program cache — 1.0 means no per-wave recompilation anywhere
        "cache_hit_waves": (
            sum(c["cache_hits"] for c in cells)
            / max(sum(c["waves"] - c["compiles"] for c in cells), 1)
        ),
        "step_cache": step_cache_stats(),
    }
    print("\nheadline:")
    for k, v in headline.items():
        print(f"  {k:>20s}: {v}")
    return {
        "bench": "client_batch",
        "quick": quick,
        "model": {"kind": MCFG.kind, "hidden": MCFG.hidden,
                  "n_layers": MCFG.n_layers,
                  "in_dim": MCFG.image_size ** 2 * MCFG.channels},
        "cells": cells,
        "headline": headline,
        "thresholds": {
            "speedup_64": 2.0 if quick else 5.0,
            "ragged_speedup_64": 1.5,
            "cache_hit_waves": 1.0,
        },
        "tolerances": {"params_max_abs_diff": 1e-5},
    }


def check(report: Dict[str, Any]) -> List[str]:
    fails = []
    for key, floor in report["thresholds"].items():
        got = report["headline"][key]
        if got < floor:
            fails.append(f"{key} = {got:.2f} < required {floor}")
    for key, ceil in report["tolerances"].items():
        got = report["headline"][key]
        if got > ceil:
            fails.append(f"{key} = {got:.2e} > allowed {ceil}")
    return fails


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI scale: fewer local steps and reps")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero if a headline threshold is missed")
    ap.add_argument("--out", default="BENCH_clients.json")
    args = ap.parse_args()
    report = run(quick=args.quick)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"\nwrote {args.out}")
    if args.check:
        fails = check(report)
        for f_ in fails:
            print(f"THRESHOLD MISS: {f_}")
        return 1 if fails else 0
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
