"""Observability overhead benchmark: the tracing/metrics tax on the hot path.

Runs the scalability workload (tests/test_campaign.py's 10k-client x
50-round churn campaign) twice — once bare, once under a full
``repro.obs.ObsPlane`` (tracer + metrics registry) — and pins the wall
clock overhead of the instrumented run in ``BENCH_obs.json``.

The budget is the tentpole's acceptance criterion: **tracing on must cost
<= 5% wall clock** on this campaign (~500k executor lifecycles, so every
span/counter touch on the engine hot path is exercised at scale).  The
call-site contract that makes this possible: engines cache
``self._trace`` (None when disabled) and resolve registry metrics once
into slotted attribute handles — the disabled path is one attribute load
and a branch.

Usage::

    PYTHONPATH=src python benchmarks/obs_overhead.py           # full run
    PYTHONPATH=src python benchmarks/obs_overhead.py --quick --check  # CI
"""
from __future__ import annotations

import argparse
import gc
import json
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.core.budget import fedscale_budget_distribution
from repro.core.campaign import AvailabilityTrace, CampaignEngine, SimClient
from repro.core.scheduler import FedHCScheduler
from repro.obs import ObsPlane


def _run_campaign(n_clients: int, n_rounds: int,
                  obs: Optional[ObsPlane]) -> Tuple[float, Any]:
    budgets = fedscale_budget_distribution(n_clients, seed=0)
    clients = [SimClient(b.client_id, b.budget, 2.0) for b in budgets]
    churn = AvailabilityTrace.periodic(
        [c.client_id for c in clients[: n_clients // 5]],
        period=400.0, duty=0.7, horizon=20_000.0, seed=3,
    )
    eng = CampaignEngine(
        FedHCScheduler, max_parallel=64, availability=churn,
        record_timeline=False, record_events=False, obs=obs,
    )
    gc.collect()                         # same GC state at every t0
    t0 = time.perf_counter()
    res = eng.run_campaign([clients] * n_rounds)
    return time.perf_counter() - t0, res


def run(quick: bool = False) -> Dict[str, Any]:
    n_clients, n_rounds, reps = (2_000, 25, 5) if quick else (10_000, 50, 4)
    # the 5% budget is pinned on the full-scale campaign, whose ~9s runs
    # average the box's frequency/contention drift away; the --quick smoke
    # (~1s runs) sees +-10% cross-invocation noise even at min/min, so its
    # gate is padded — it catches a broken disabled-path or a regression to
    # per-span allocation (those showed up as +20..30%), not a 5.1% miss
    ceiling = 0.15 if quick else 0.05

    _run_campaign(200, 2, None)          # warm-up: imports, allocator
    _run_campaign(200, 2, ObsPlane(trace=True))
    # exclude the host process's baseline heap (pytest, test imports) from
    # every future GC pass: collection cost then depends only on what the
    # bench itself allocates, so standalone and in-pytest runs agree
    gc.freeze()
    # one untimed run at the REAL size: whichever config runs first would
    # otherwise pocket the CPU's turbo/cold-cache head start (a one-sided
    # bias that min/min cannot cancel)
    _run_campaign(n_clients, n_rounds, None)
    base_times: List[float] = []
    obs_times: List[float] = []
    ratios: List[float] = []
    events = 0
    completed_base = completed_obs = 0
    # machine noise on a shared CI box dwarfs a few percent of signal, so
    # each rep times the two configs back to back (alternating order to
    # cancel drift); the headline estimator is chosen below from the rep
    # mins and the per-pair ratios
    for rep in range(reps):
        order = (None, "obs") if rep % 2 == 0 else ("obs", None)
        walls = {}
        for kind in order:
            obs = ObsPlane(trace=True) if kind else None
            wall, res = _run_campaign(n_clients, n_rounds, obs)
            walls[kind] = wall
            if kind:
                completed_obs = res.total_completed
                events = len(obs.tracer)
            else:
                completed_base = res.total_completed
        base_times.append(walls[None])
        obs_times.append(walls["obs"])
        ratios.append(walls["obs"] / walls[None])
        print(f"rep {rep}: bare {walls[None]:6.2f}s   "
              f"obs {walls['obs']:6.2f}s   ratio {ratios[-1]:.3f}   "
              f"events {events}", flush=True)

    base_s, obs_s = min(base_times), min(obs_times)
    # two estimators, each immune to a different noise shape: min/min
    # cancels one-sided spikes (a contaminated run is never the min) but
    # not slow monotone drift (one config's min can land in a window the
    # other never saw); the best back-to-back pair ratio cancels drift
    # (both halves share the window) but not a spike inside a pair.  In
    # the noise-only-adds-time model both over-estimate true cost, so the
    # smaller is the least-contaminated bound.
    ratio = min(obs_s / base_s, min(ratios))
    headline = {
        "base_s": base_s,
        "obs_s": obs_s,
        "overhead_frac": ratio - 1.0,
        "min_over_min": obs_s / base_s - 1.0,
        "best_pair": min(ratios) - 1.0,
        "trace_events": events,
        "clients_completed": completed_obs,
    }
    print(f"\nbare {base_s:.2f}s  obs {obs_s:.2f}s  "
          f"overhead {headline['overhead_frac'] * 100:+.1f}% "
          f"(min of min/min {headline['min_over_min'] * 100:+.1f}% and "
          f"best pair {headline['best_pair'] * 100:+.1f}%)  "
          f"({events} trace events)")
    return {
        "bench": "obs_overhead",
        "quick": quick,
        "n_clients": n_clients,
        "n_rounds": n_rounds,
        "reps": reps,
        "base_times_s": base_times,
        "obs_times_s": obs_times,
        "pair_ratios": ratios,
        "headline": headline,
        "thresholds": {"overhead_frac_max": ceiling},
        "sanity": {"identical_results": completed_base == completed_obs},
    }


def check(report: Dict[str, Any]) -> List[str]:
    fails: List[str] = []
    h = report["headline"]
    ceil = report["thresholds"]["overhead_frac_max"]
    if h["overhead_frac"] > ceil:
        fails.append(f"overhead_frac = {h['overhead_frac']:.3f} "
                     f"> allowed {ceil}")
    if h["trace_events"] <= 0:
        fails.append("instrumented run recorded no trace events "
                     "(measuring a no-op)")
    if not report["sanity"]["identical_results"]:
        fails.append("instrumented run changed campaign results")
    return fails


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI scale: 2k clients x 25 rounds, 5 paired reps, "
                         "noise-padded gate (the 5%% budget is pinned on "
                         "the full run)")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero if the overhead budget is missed")
    ap.add_argument("--out", default="BENCH_obs.json")
    args = ap.parse_args()
    report = run(quick=args.quick)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"\nwrote {args.out}")
    if args.check:
        fails = check(report)
        for f_ in fails:
            print(f"THRESHOLD MISS: {f_}")
        return 1 if fails else 0
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
