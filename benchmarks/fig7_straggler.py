"""Fig 7 — straggler acceleration: FedHC reflects S1–S4, the estimator can't.

S0: base model, full GPU.  S1: +hardware constraint (25% budget).
S2: +bigger batch.  S3: +fewer layers.  S4: +shorter sequences.
FedHC (framework-provided runtime) shows the staircase coming back down;
the FedScale-style estimator only moves at S1.
"""
from __future__ import annotations

from typing import List

import jax

from benchmarks.common import Row
from benchmarks.fig6_factors import _time
from repro.core.budget import WorkloadSpec
from repro.core.estimator import FedScaleEstimator
from repro.core.runtime import MeasuredRuntime
from repro.models.small import SmallModelConfig

BUDGET = 25.0


def run() -> List[Row]:
    rt = MeasuredRuntime()
    est = FedScaleEstimator()
    rows: List[Row] = []
    base = SmallModelConfig(kind="lstm", n_classes=2, hidden=64, n_layers=2, vocab_size=512)

    stages = {
        "S0": (base, 32, 64, 100.0, 8),
        "S1": (base, 32, 64, BUDGET, 8),
        "S2": (base, 64, 64, BUDGET, 4),                      # bigger batch
        "S3": (base.replace(n_layers=1), 64, 64, BUDGET, 4),  # fewer layers
        "S4": (base.replace(n_layers=1), 64, 16, BUDGET, 4),  # shorter seq
    }
    prev_fedhc = None
    for name, (mcfg, bs, seq, budget, steps) in stages.items():
        t_fedhc = _time(rt, mcfg, batch_size=bs, seq_len=seq, n_batches=steps) / (budget / 100.0)
        wl = WorkloadSpec(model="lstm", n_layers=mcfg.n_layers, seq_len=seq,
                          batch_size=bs, n_batches=steps)
        t_est = est.seconds(wl, speed_factor=budget / 100.0)
        rows.append(Row(f"fig7.{name}", t_fedhc * 1e6,
                        {"fedhc_s": t_fedhc, "fedscale_est_s": t_est}))
        prev_fedhc = t_fedhc
    # derived check: S4 << S1 under FedHC; estimator flat S1..S4 modulo volume
    return rows
