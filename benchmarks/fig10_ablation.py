"""Fig 10 — ablation: baseline (+process switching) → +dynamic process
management → +resource-aware scheduling → +resource sharing →
+multi-tenant fabric (two concurrent jobs on the shared pool).

Execution time per global round at 3/10/100 participants; every module must
reduce (or at worst not increase) the round time.  The fabric row reports
the aggregate time for TWO such jobs — shared pool vs serial.
"""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import Row
from repro.core.budget import fedscale_budget_distribution
from repro.core.campaign import CampaignEngine
from repro.core.fabric import PoolFabric
from repro.core.scheduler import FedHCScheduler, GreedyScheduler
from repro.core.simulator import RoundSimulator, SimClient

WORK_S = 2.0

VARIANTS = {
    # name: (scheduler, manager_mode, max_parallel, theta)
    "baseline": (GreedyScheduler, "fixed", 4, 100.0),
    "+dynamic_proc": (GreedyScheduler, "dynamic", 64, 100.0),
    "+scheduler": (FedHCScheduler, "dynamic", 64, 100.0),
    "+sharing": (FedHCScheduler, "dynamic", 64, 150.0),
}


def run() -> List[Row]:
    budgets = fedscale_budget_distribution(2800, seed=0)
    rows: List[Row] = []
    for n in (3, 10, 100):
        rng = np.random.default_rng(n)
        idx = rng.choice(len(budgets), size=n, replace=False)
        clients = [SimClient(int(i), budgets[i].budget, WORK_S) for i in idx]
        durations = {}
        for name, (sched, mode, par, theta) in VARIANTS.items():
            sim = RoundSimulator(sched, manager_mode=mode, max_parallel=par, theta=theta)
            res, _ = sim.run(clients)
            durations[name] = res.duration

        # +multi_tenant: TWO of these jobs at once on one fabric-shared
        # pool vs serially — aggregate time for the pair
        other = [SimClient(10_000 + c.client_id, c.budget, c.work)
                 for c in clients]
        serial = 2 * CampaignEngine(
            FedHCScheduler, theta=150.0, max_parallel=64
        ).run_round(clients).duration
        fab = PoolFabric(total_slots=64, capacity=100.0, lease_ttl=5.0)
        fab.add_tenant("A", weight=1.0, theta=150.0)
        fab.add_tenant("B", weight=1.0, theta=150.0)
        shared = fab.run({"A": [clients], "B": [other]})
        durations["+multi_tenant_pair"] = max(
            r.duration for r in shared.values()
        )
        durations["serial_pair"] = serial

        rows.append(Row(
            f"fig10.participants_{n}", durations["+sharing"] * 1e6,
            {**{k: v for k, v in durations.items()},
             "total_speedup": durations["baseline"] / durations["+sharing"],
             "pair_speedup": serial / durations["+multi_tenant_pair"]},
        ))
    return rows
