"""Fig 10 — ablation: baseline (+process switching) → +dynamic process
management → +resource-aware scheduling → +resource sharing.

Execution time per global round at 3/10/100 participants; every module must
reduce (or at worst not increase) the round time.
"""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import Row
from repro.core.budget import fedscale_budget_distribution
from repro.core.scheduler import FedHCScheduler, GreedyScheduler
from repro.core.simulator import RoundSimulator, SimClient

WORK_S = 2.0

VARIANTS = {
    # name: (scheduler, manager_mode, max_parallel, theta)
    "baseline": (GreedyScheduler, "fixed", 4, 100.0),
    "+dynamic_proc": (GreedyScheduler, "dynamic", 64, 100.0),
    "+scheduler": (FedHCScheduler, "dynamic", 64, 100.0),
    "+sharing": (FedHCScheduler, "dynamic", 64, 150.0),
}


def run() -> List[Row]:
    budgets = fedscale_budget_distribution(2800, seed=0)
    rows: List[Row] = []
    for n in (3, 10, 100):
        rng = np.random.default_rng(n)
        idx = rng.choice(len(budgets), size=n, replace=False)
        clients = [SimClient(int(i), budgets[i].budget, WORK_S) for i in idx]
        durations = {}
        for name, (sched, mode, par, theta) in VARIANTS.items():
            sim = RoundSimulator(sched, manager_mode=mode, max_parallel=par, theta=theta)
            res, _ = sim.run(clients)
            durations[name] = res.duration
        rows.append(Row(
            f"fig10.participants_{n}", durations["+sharing"] * 1e6,
            {**{k: v for k, v in durations.items()},
             "total_speedup": durations["baseline"] / durations["+sharing"]},
        ))
    return rows
