"""Fig 8 — impact of client heterogeneity on global convergence.

(a) workload heterogeneity: adding an extra local (personalization) model
    doubles client compute → slower convergence against the simulated clock;
(b) hardware heterogeneity: constrained budgets vs every client at 100%.

Real federated training on synthetic Non-IID shards; x-axis is the
continuous simulated clock produced by the FedHC campaign engine (one
clock across all rounds, every simulated lifecycle transition mirrored
through the FLServer control plane).
"""
from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.core.budget import uniform_budgets
from repro.fed.trainer import FedConfig, FederatedTrainer, build_fl_clients
from repro.models.small import SmallModelConfig

BUDGETS = [10, 25, 40, 55, 70, 85, 100, 30, 60, 90]
ROUNDS = 8


def _run(mcfg: SmallModelConfig, budgets, seed=0) -> dict:
    clients, test = build_fl_clients(
        mcfg, budgets, "cifar10", n_samples=1500, batch_size=16, n_batches=4, seed=seed
    )
    fed = FedConfig(rounds=ROUNDS, participants_per_round=8, local_steps=4,
                    learning_rate=0.1, seed=seed)
    tr = FederatedTrainer(mcfg, clients, fed, test_batch=test)
    hist = tr.run()
    # the campaign engine's clock is the authoritative x-axis, and the
    # mirrored control plane must have seen every simulated completion
    assert tr.engine.now == hist[-1]["sim_clock"]
    n_done = sum(
        1 for st in tr.engine.server.monitor.state.values() if st == "done"
    )
    return {
        "final_acc": hist[-1]["test_acc"],
        "sim_time_s": hist[-1]["sim_clock"],
        "acc_per_sim_s": hist[-1]["test_acc"] / max(hist[-1]["sim_clock"], 1e-9),
        "protocol_clients_done": n_done,
    }


def run() -> List[Row]:
    rows: List[Row] = []
    base = SmallModelConfig(kind="cnn", n_classes=10, hidden=64, n_layers=2,
                            image_size=32, channels=3)
    budgets = uniform_budgets(BUDGETS)

    plain = _run(base, budgets)
    extra = _run(base.replace(extra_local_model=True), budgets)
    rows.append(Row("fig8a.workload_plain", plain["sim_time_s"] * 1e6, plain))
    rows.append(Row("fig8a.workload_extra_model", extra["sim_time_s"] * 1e6, extra))
    rows.append(Row("fig8a.extra_model_slowdown", 0.0, {
        "time_ratio": extra["sim_time_s"] / max(plain["sim_time_s"], 1e-9)}))

    homog = _run(base, uniform_budgets([100.0] * len(BUDGETS)), seed=1)
    heterog = _run(base, budgets, seed=1)
    rows.append(Row("fig8b.homogeneous_hw", homog["sim_time_s"] * 1e6, homog))
    rows.append(Row("fig8b.heterogeneous_hw", heterog["sim_time_s"] * 1e6, heterog))
    rows.append(Row("fig8b.heterogeneity_slowdown", 0.0, {
        "time_ratio": heterog["sim_time_s"] / max(homog["sim_time_s"], 1e-9)}))
    return rows
