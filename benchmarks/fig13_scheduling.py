"""Fig 13 — scheduling case study: 8 participants (A–H) with budgets
10,15,30,80,65,40,50,10; greedy vs resource-aware double-pointer.

Paper: 213 s → 128 s (1.66×).  Work-per-client is calibrated so the greedy
round lands near the paper's 213 s; the speedup ratio is the reproduced
quantity (it is independent of the calibration constant).
"""
from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.core.scheduler import FedHCScheduler, GreedyScheduler
from repro.core.simulator import RoundSimulator, SimClient

BUDGETS = [10, 15, 30, 80, 65, 40, 50, 10]  # A..H
WORK_S = 10.65  # calibrated: greedy ≈ 213 s


def run() -> List[Row]:
    clients = [SimClient(i, b, WORK_S) for i, b in enumerate(BUDGETS)]
    rows: List[Row] = []
    results = {}
    for name, sched in (("greedy", GreedyScheduler), ("fedhc", FedHCScheduler)):
        res, _ = RoundSimulator(sched, max_parallel=8).run(clients)
        results[name] = res
        # vacancy: area between admitted budget and the y=100 line (Fig 13b)
        vac = sum((100.0 - min(seg.total_budget, 100.0)) * (seg.t1 - seg.t0)
                  for seg in res.timeline)
        rows.append(Row(
            f"fig13.{name}", res.duration * 1e6,
            {"duration_s": res.duration, "vacancy_pct_s": vac,
             "utilization": res.utilization(),
             "straggler_H_start_s": res.spans[7].start if 7 in res.spans else -1},
        ))
    rows.append(Row(
        "fig13.speedup", 0.0,
        {"ratio": results["greedy"].duration / results["fedhc"].duration,
         "paper_ratio": 213.0 / 128.0},
    ))
    return rows
