"""Benchmark driver — one module per paper table/figure + roofline report.

Prints ``name,us_per_call,derived`` CSV rows (derived = ';'-joined k=v).

    PYTHONPATH=src python -m benchmarks.run [--only fig9,fig13] [--skip fig8]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks.common import print_rows

MODULES = [
    ("fig6", "benchmarks.fig6_factors"),
    ("fig7", "benchmarks.fig7_straggler"),
    ("fig8", "benchmarks.fig8_convergence"),
    ("fig9", "benchmarks.fig9_scalability"),
    ("fig10", "benchmarks.fig10_ablation"),
    ("fig11", "benchmarks.fig11_dynamic_process"),
    ("fig13", "benchmarks.fig13_scheduling"),
    ("fig14", "benchmarks.fig14_sharing"),
    ("roofline", "benchmarks.roofline_report"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma list of module keys")
    ap.add_argument("--skip", default="", help="comma list of module keys")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    skip = set(args.skip.split(",")) if args.skip else set()

    print("name,us_per_call,derived")
    failures = 0
    for key, modname in MODULES:
        if (only is not None and key not in only) or key in skip:
            continue
        t0 = time.time()
        try:
            mod = __import__(modname, fromlist=["run"])
            rows = mod.run()
            print_rows(rows)
            print(f"# {key} done in {time.time()-t0:.1f}s", file=sys.stderr, flush=True)
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{key}.FAILED,0,", flush=True)
    if failures:
        raise SystemExit(f"{failures} benchmark modules failed")


if __name__ == "__main__":
    main()
